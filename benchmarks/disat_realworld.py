"""Paper Fig 13: DiSAT over "real-world" data, Hyperbolic vs Hilbert.

SISAP `colors` (112-d, ~113k) and `nasa` (20-d, ~40k) are not
redistributable offline; stand-ins are clustered Gaussian mixtures with
matched dimensionality (the clustered regime is what makes these sets
metrically "real" — uniform data would misrepresent them; DESIGN.md §7).
10% of the data queries the other 90% at thresholds returning ~0.01%,
0.1%, 1% of the set (the paper's protocol).  The reproduction target is
the Hilbert/Hyperbolic cost ratio; §6.5 identity is asserted.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import check_vs_oracle
from repro.core import bruteforce
from repro.core.tree import build_disat, search_sat
from repro.data.synthetic import metric_space

DATASETS = {
    # name: (dim, n_default, clusters)
    "colors*": (112, 24000, 24),
    "nasa*": (20, 16000, 16),
}


def run(scale: float = 1.0, nq: int = 96, seed: int = 0, check: bool = True):
    rows = []
    for name, (dim, n0, clusters) in DATASETS.items():
        n = int(n0 * scale)
        pts = metric_space(seed, n, dim, clustered=clusters)
        rng = np.random.default_rng(seed + 1)
        qidx = rng.choice(n, nq, replace=False)
        mask = np.zeros(n, bool)
        mask[qidx] = True
        queries, data = pts[mask], pts[~mask]
        nd = data.shape[0]

        # thresholds for ~0.01 / 0.1 / 1 % selectivity
        from repro.core import idim as idim_lib, metrics as metrics_lib
        m = metrics_lib.get("euclidean")
        d_all = np.asarray(m.pairwise(queries, data)).reshape(-1)
        ts = {f: float(np.quantile(d_all, f)) for f in
              (1e-4, 1e-3, 1e-2)}

        tree = build_disat(data, "euclidean", seed=seed + 2)
        for frac, t in ts.items():
            ref_sets = None
            if check:
                _, ref_sets = bruteforce.range_search(
                    data, queries, t, metric_name="euclidean")
            row = {"dataset": name, "sel": frac, "n": nd,
                   "fanout": tree.max_fanout}
            mech_sets = {}
            for mech in ("hyperbolic", "hilbert"):
                st = search_sat(tree, queries, t, metric_name="euclidean",
                                mechanism=mech, r_cap=4096,
                                stack_cap=8192)
                assert not np.asarray(st.stack_overflow).any()
                mech_sets[mech] = st.result_sets()
                if check:
                    check_vs_oracle(data, queries, t, mech_sets[mech],
                                    ref_sets, context=f"{name}/{mech}")
                row[mech] = round(
                    100 * float(np.mean(np.asarray(st.n_dist))) / nd, 3)
            # paper §6.5: mechanisms must agree EXACTLY with each other
            assert mech_sets["hyperbolic"] == mech_sets["hilbert"], name
            row["ratio"] = round(row["hilbert"] / row["hyperbolic"], 3)
            rows.append(row)
    return rows


def main(argv=None):
    print("fig13_disat_realworld (mean distance evals per query, % of n)")
    print("dataset,selectivity,hyperbolic,hilbert,ratio,fanout")
    for r in run():
        print(f"{r['dataset']},{r['sel']},{r['hyperbolic']},"
              f"{r['hilbert']},{r['ratio']},{r['fanout']}")


if __name__ == "__main__":
    main()
