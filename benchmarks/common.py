"""Shared benchmark utilities: spaces, thresholds, timing."""

from __future__ import annotations

import time

import numpy as np

from repro.core import idim as idim_lib
from repro.core import metrics as metrics_lib
from repro.data.synthetic import metric_space

SPACES = [("euclidean", "euc"), ("jsd", "jsd"), ("triangular", "tri")]


def make_space(metric_name: str, dim: int, n: int, nq: int, seed: int = 0):
    """Paper §6.1: uniform unit hypercube; simplex metrics row-normalised
    (footnote 6 — euc is NOT normalised; jsd/tri are)."""
    simplex = metrics_lib.get(metric_name).simplex
    pts = metric_space(seed, n + nq, dim, simplex=simplex)
    return pts[:n], pts[n:]


def thresholds_for(metric_name: str, data, queries, ns=(1, 4, 16)):
    m = metrics_lib.get(metric_name)
    return idim_lib.calibrate_thresholds(m, data, queries, ns=ns)


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6         # us


def check_vs_oracle(data, queries, t, sets, ref_sets, *, tol=1e-4,
                    context=""):
    """Exact-search check vs the brute-force oracle, tolerant ONLY to
    boundary ids whose f64 distance is within ``tol`` of t (the oracle
    and the traversal use different f32 reduction orders; ids that far
    inside/outside the ball must never differ).  Mechanism-vs-mechanism
    comparisons stay exact (paper §6.5)."""
    data64 = np.asarray(data, np.float64)
    q64 = np.asarray(queries, np.float64)
    for i, (s, r) in enumerate(zip(sets, ref_sets)):
        for mid in s.symmetric_difference(r):
            d = np.linalg.norm(q64[i] - data64[mid])
            assert abs(d - t) < tol, (
                f"{context}: q{i} id {mid} differs with |d-t|="
                f"{abs(d - t):.3e} (not a boundary artifact)")
