"""Paper Table 3 / Figs 8-11: exclusion power of Hyperbolic vs Hilbert vs
single-Pivot, per space and threshold.

Power = P(random query can exclude the opposing semispace) over random
pivot pairs.  Euclidean margins run through the FUSED Pallas kernel
(repro.kernels.exclusion_step) — the exact compute this benchmark's TPU
serving path would execute; simplex metrics use the jnp path.

Paper validation (n=10^6, t1): euc_10 hyperbolic 12.2%, hilbert 44.3%,
pivot 31.9%; euc_14 0.9% / 18.5% / 8.8%.  Power depends only on the
distance distribution => small-n estimates converge fast.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import SPACES, make_space, thresholds_for
from repro.core import metrics as metrics_lib
from repro.kernels import ops as kernel_ops

PAPER = {  # (space, mech) -> % at t1/t4/t16 (Table 3)
    ("euc_10", "hyperbolic"): (12.2, 7.6, 4.3),
    ("euc_10", "hilbert"): (44.3, 37.7, 30.8),
    ("euc_10", "pivot"): (31.9, 25.1, 18.7),
    ("euc_14", "hyperbolic"): (0.9, 0.4, 0.2),
    ("euc_14", "hilbert"): (18.5, 14.2, 10.3),
    ("jsd_10", "hyperbolic"): (11.4, 6.3, 3.0),
    ("jsd_10", "hilbert"): (42.6, 34.4, 26.4),
    ("tri_10", "hyperbolic"): (8.1, 4.1, 1.8),
    ("tri_10", "hilbert"): (38.0, 29.7, 21.8),
}


def exclusion_power(metric_name: str, data: np.ndarray,
                    queries: np.ndarray, pivot_pairs: int, t: float,
                    seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    n = data.shape[0]
    m = metrics_lib.get(metric_name)
    i = rng.choice(n, pivot_pairs, replace=False)
    j = rng.choice(n, pivot_pairs, replace=False)
    clash = i == j
    j = np.where(clash, (j + 1) % n, j)
    p1, p2 = data[i], data[j]

    if metric_name == "euclidean":
        d12 = np.linalg.norm(
            p1.astype(np.float64) - p2.astype(np.float64), axis=-1
        ).astype(np.float32)
        hyp, hil = kernel_ops.exclusion_margins(queries, p1, p2, d12)
        hyp, hil = np.asarray(hyp), np.asarray(hil)
        d1 = np.asarray(kernel_ops.pairwise_distance(
            queries, p1, "euclidean"))
    else:
        d1 = np.asarray(m.pairwise(queries, p1))
        d2 = np.asarray(m.pairwise(queries, p2))
        d12 = np.asarray(
            m.pairwise(p1, p2)).diagonal() if pivot_pairs <= 512 else None
        if d12 is None:
            from repro.core.idim import rowwise_distance
            d12 = np.asarray(rowwise_distance(m, p1, p2))
        hyp = 0.5 * (d1 - d2)
        hil = np.where(d12[None, :] > 1e-9,
                       (d1 ** 2 - d2 ** 2) / (2 * np.maximum(d12, 1e-12)),
                       0.0)

    # two-sided: a query excludes if EITHER side is excludable
    p_hyp = float(np.mean(np.abs(hyp) > t))
    p_hil = float(np.mean(np.abs(hil) > t))

    # single-pivot (Fig 10): median-radius ball around p1
    sample = data[rng.choice(n, min(n, 4096), replace=False)]
    if metric_name == "euclidean":
        dmed = np.asarray(kernel_ops.pairwise_distance(
            sample, p1, "euclidean"))
    else:
        dmed = np.asarray(m.pairwise(sample, p1))
    med = np.median(dmed, axis=0)                       # (P,)
    p_piv = float(np.mean(np.abs(d1 - med[None, :]) > t))
    return {"hyperbolic": p_hyp, "hilbert": p_hil, "pivot": p_piv}


def run(n: int = 32768, nq: int = 256, pivot_pairs: int = 256,
        dims=(6, 8, 10, 12, 14), seed: int = 0):
    rows = []
    for metric_name, short in SPACES:
        for d in dims:
            data, queries = make_space(metric_name, d, n, nq, seed)
            ts = thresholds_for(metric_name, data, queries)
            for tn in (1, 4, 16):
                pw = exclusion_power(metric_name, data, queries,
                                     pivot_pairs, ts[tn], seed)
                rows.append({
                    "space": f"{short}_{d}", "t": f"t{tn}",
                    **{k: round(100 * v, 1) for k, v in pw.items()},
                })
    return rows


def main(argv=None):
    print("table3_exclusion_power (percent)")
    print("space,t,hyperbolic,hilbert,pivot,hilbert_over_hyperbolic")
    for r in run():
        ratio = round(r["hilbert"] / max(r["hyperbolic"], 1e-3), 2)
        print(f"{r['space']},{r['t']},{r['hyperbolic']},{r['hilbert']},"
              f"{r['pivot']},{ratio}")


if __name__ == "__main__":
    main()
