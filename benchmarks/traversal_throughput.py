"""Frontier-batched traversal throughput (DESIGN.md §3).

For both engines (GHT binary / DiSAT) x all four paper metrics, sweep
the frontier width B over {1, 4, 8, 16} and report:

  * while_loop iterations (the serialised-step count B attacks)
  * total n_dist (MUST be invariant in B — asserted, with identical
    result sets: frontier batching changes schedule, never work)
  * wall-clock per search call (jitted, post-compile)

Machine-readable output: ``main(json_path=...)`` (and the run.py driver)
writes BENCH_traversal.json for the perf trajectory.

  PYTHONPATH=src python -m benchmarks.traversal_throughput
"""

from __future__ import annotations

import json
import platform
import time

import jax
import numpy as np

from repro.core.tree import (build_disat, build_ght, search_binary_tree,
                             search_sat)
from benchmarks.common import make_space

WIDTHS = (1, 4, 8, 16)

# (metric, threshold) — thresholds sized for ~1% selectivity on the
# §6.1 synthetic spaces, matching the system tests
CASES = [("euclidean", 0.32), ("cosine", 0.18),
         ("jsd", 0.09), ("triangular", 0.12)]


def _run_once(search, tree, queries, t, metric, b):
    st = search(tree, queries, t, metric_name=metric,
                mechanism="hilbert", frontier=b)
    jax.block_until_ready(st.res_cnt)
    return st


def _sweep(engine, search, tree, queries, t, metric, *, widths, repeat):
    rows = []
    base = None
    for b in widths:
        st = _run_once(search, tree, queries, t, metric, b)  # compile+run
        t0 = time.perf_counter()
        for _ in range(repeat):
            st = _run_once(search, tree, queries, t, metric, b)
        wall_us = (time.perf_counter() - t0) / repeat * 1e6
        assert not np.asarray(st.stack_overflow).any(), \
            f"{engine}/{metric} B={b}: stack overflow"
        assert not np.asarray(st.overflow).any(), \
            f"{engine}/{metric} B={b}: result overflow (raise r_cap)"
        if base is None:
            base = st
        sets_ok = st.result_sets() == base.result_sets()
        nd_ok = np.array_equal(np.asarray(st.n_dist),
                               np.asarray(base.n_dist))
        assert sets_ok and nd_ok, \
            f"{engine}/{metric} B={b}: parity broken (sets={sets_ok})"
        rows.append({
            "engine": engine, "metric": metric, "frontier": b,
            "iters": int(st.iters),
            "n_dist_total": int(np.sum(np.asarray(st.n_dist))),
            "wall_us": round(wall_us, 1),
            "identical_to_b1": bool(sets_ok and nd_ok),
        })
        r = rows[-1]
        print(f"  {engine:5s} {metric:10s} B={b:2d}  iters={r['iters']:5d} "
              f"n_dist={r['n_dist_total']:7d}  {r['wall_us']:9.0f} us")
    return rows


def main(*, n=2000, nq=32, repeat=3, json_path="BENCH_traversal.json",
         widths=WIDTHS) -> dict:
    # the first swept width is the parity baseline; keep 1 in front so
    # every row is compared against the single-pop engine
    widths = tuple(widths)
    if widths[0] != 1:
        widths = (1,) + tuple(b for b in widths if b != 1)
    rows = []
    print("engine  metric      B   iters  n_dist      wall/call")
    for metric, t in CASES:
        data, queries = make_space(metric, 8, n, nq)
        ght = build_ght(data, metric, leaf_size=16, seed=1)
        rows += _sweep("ght", search_binary_tree, ght, queries, t, metric,
                       widths=widths, repeat=repeat)
        sat = build_disat(data[: max(n // 2, 1)], metric, seed=2)
        rows += _sweep("disat", search_sat, sat, queries, t, metric,
                       widths=widths, repeat=repeat)

    # headline ratios: iteration cut per engine at B=8 (the acceptance
    # width) when swept, else the largest swept width > 1
    b_hi = 8 if 8 in widths else \
        (max(b for b in widths if b > 1) if len(widths) > 1 else 1)
    summary = {}
    for engine in ("ght", "disat"):
        i1 = sum(r["iters"] for r in rows
                 if r["engine"] == engine and r["frontier"] == 1)
        ih = sum(r["iters"] for r in rows
                 if r["engine"] == engine and r["frontier"] == b_hi)
        summary[engine] = {
            "iters_b1": i1, f"iters_b{b_hi}": ih,
            f"iter_reduction_b{b_hi}": round(i1 / max(ih, 1), 2),
        }
        print(f"{engine}: iters B=1 {i1} -> B={b_hi} {ih} "
              f"({summary[engine][f'iter_reduction_b{b_hi}']}x fewer)")

    result = {
        "bench": "traversal_throughput",
        "n": n, "nq": nq, "dim": 8, "repeat": repeat,
        "widths": list(widths),
        "device": jax.devices()[0].platform,
        "python": platform.python_version(),
        "jax": jax.__version__,
        "rows": rows,
        "summary": summary,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {json_path}")
    return result


if __name__ == "__main__":
    main()
