"""Benchmark driver: one function per paper table/figure, plus the
system-performance benches (frontier traversal).

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run table3     # one table
  PYTHONPATH=src python -m benchmarks.run traversal  # frontier sweep

Output: per-table CSV blocks (name, values, derived ratios), then a
summary `name,us_per_call,derived` line per table for harness parsing.
The ``traversal`` / ``knn`` benches additionally write the
machine-readable ``BENCH_traversal.json`` / ``BENCH_knn.json`` (perf
trajectory artifacts).
"""

from __future__ import annotations

import sys
import time

from benchmarks import (disat_realworld, exclusion_power, ght_mht_cost,
                        idim_thresholds, knn_cost, traversal_throughput)

TABLES = {
    "table2": idim_thresholds.main,
    "table3": exclusion_power.main,
    "table4": ght_mht_cost.main,
    "fig13": disat_realworld.main,
    "traversal": traversal_throughput.main,
    "knn": knn_cost.main,
}


def main() -> None:
    which = sys.argv[1:] or list(TABLES)
    summary = []
    for name in which:
        fn = TABLES[name]
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        summary.append((name, dt * 1e6))
        print()
    print("name,us_per_call,derived")
    for name, us in summary:
        print(f"{name},{us:.0f},see-table-above")


if __name__ == "__main__":
    main()
