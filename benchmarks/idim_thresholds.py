"""Paper Table 2: IDIM + query thresholds per space.

Validation targets (paper values at n=10^6): euc_6 IDIM 7.70, euc_10
13.36, euc_14 19.13, jsd_10 9.49, tri_10 10.46 — IDIM is a property of
the distance distribution, so a smaller sample reproduces it closely.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import SPACES, make_space, thresholds_for, timed
from repro.core import idim as idim_lib
from repro.core import metrics as metrics_lib

PAPER_IDIM = {
    ("euc", 6): 7.698, ("euc", 8): 10.40, ("euc", 10): 13.36,
    ("euc", 12): 16.23, ("euc", 14): 19.13,
    ("jsd", 6): 5.162, ("jsd", 8): 7.273, ("jsd", 10): 9.486,
    ("jsd", 12): 11.51, ("jsd", 14): 13.69,
    ("tri", 6): 5.754, ("tri", 8): 8.181, ("tri", 10): 10.46,
    ("tri", 12): 13.02, ("tri", 14): 15.60,
}


def run(n: int = 65536, nq: int = 96, dims=(6, 8, 10, 12, 14),
        seed: int = 0):
    rows = []
    for metric_name, short in SPACES:
        m = metrics_lib.get(metric_name)
        for d in dims:
            data, queries = make_space(metric_name, d, n, nq, seed)
            (val, us) = timed(
                lambda: float(idim_lib.idim(m, data, jax.random.PRNGKey(0),
                                            n_pairs=8192)))
            ts = thresholds_for(metric_name, data, queries)
            paper = PAPER_IDIM.get((short, d))
            rows.append({
                "space": f"{short}_{d}", "idim": round(val, 3),
                "paper_idim": paper,
                "rel_err": round(abs(val - paper) / paper, 3) if paper
                else None,
                "t1": round(ts[1], 4), "t4": round(ts[4], 4),
                "t16": round(ts[16], 4), "us": us,
            })
    return rows


def main(argv=None):
    print("table2_idim_thresholds")
    print("space,idim,paper_idim,rel_err,t1,t4,t16")
    for r in run():
        print(f"{r['space']},{r['idim']},{r['paper_idim']},{r['rel_err']},"
              f"{r['t1']},{r['t4']},{r['t16']}")


if __name__ == "__main__":
    main()
