"""k-NN distance-evaluation cost: Hilbert vs Hyperbolic under the
shrinking-radius engines (DESIGN.md §8).

Sweeps k ∈ {1, 10, 100} × the four four-point paper metrics ×
mechanism × frontier B ∈ {1, 8} on both engines (MHT binary / DiSAT).
Every cell is cross-checked against ``bruteforce.knn`` (ids must be
identical — the k-set is exact regardless of B), and per (engine,
metric, k) the hilbert/hyperbolic ``n_dist`` ratio is the headline —
the k-NN mirror of the paper's Table 4 range-query ratios.

Unlike range search, k-NN ``n_dist`` is order-sensitive: B changes the
granularity at which the radius shrinks, so cost varies with B (each
row records it) while the returned k-set never does.

  PYTHONPATH=src python -m benchmarks.knn_cost
"""

from __future__ import annotations

import json
import platform
import time

import jax
import numpy as np

from repro.core import bruteforce
from repro.core.tree import (build_disat, build_mht, check_complete,
                             knn_search_binary_tree, knn_search_sat)
from benchmarks.common import make_space

KS = (1, 10, 100)
WIDTHS = (1, 8)
METRICS = ("euclidean", "cosine", "jsd", "triangular")


def _sweep(engine, search, tree, queries, metric, bf, *, repeat):
    rows = []
    for k in KS:
        bf_d, bf_i = bf[k]
        for mech in ("hyperbolic", "hilbert"):
            for b in WIDTHS:
                st = search(tree, queries, k, metric_name=metric,
                            mechanism=mech, frontier=b)  # compile+run
                jax.block_until_ready(st.ids)
                t0 = time.perf_counter()
                for _ in range(repeat):
                    st = search(tree, queries, k, metric_name=metric,
                                mechanism=mech, frontier=b)
                    jax.block_until_ready(st.ids)
                wall_us = (time.perf_counter() - t0) / repeat * 1e6
                check_complete(st, context=f"{engine}/{metric} k={k} B={b}")
                assert np.array_equal(np.asarray(st.ids), bf_i), \
                    f"{engine}/{metric} k={k} {mech} B={b}: ids differ " \
                    "from brute force"
                np.testing.assert_allclose(
                    np.asarray(st.dists), bf_d, atol=1e-5, rtol=1e-5)
                rows.append({
                    "engine": engine, "metric": metric, "k": k,
                    "mechanism": mech, "frontier": b,
                    "iters": int(st.iters),
                    "n_dist_mean": float(np.mean(np.asarray(st.n_dist))),
                    "wall_us": round(wall_us, 1),
                    "exact": True,
                })
                r = rows[-1]
                print(f"  {engine:5s} {metric:10s} k={k:3d} {mech:10s} "
                      f"B={b}  n_dist={r['n_dist_mean']:7.0f}  "
                      f"iters={r['iters']:5d}  {r['wall_us']:9.0f} us")
    return rows


def main(*, n=2000, nq=16, repeat=3, json_path="BENCH_knn.json") -> dict:
    rows = []
    print("engine  metric     k    mechanism  B  n_dist   iters  wall/call")
    for metric in METRICS:
        data, queries = make_space(metric, 8, n, nq)
        bf = {}
        for k in KS:
            d, i = bruteforce.knn(np.asarray(data), np.asarray(queries),
                                  metric_name=metric, k=k)
            bf[k] = (np.asarray(d), np.asarray(i))
        mht = build_mht(data, metric, leaf_size=16, seed=1)
        rows += _sweep("mht", knn_search_binary_tree, mht, queries,
                       metric, bf, repeat=repeat)
        sat = build_disat(data, metric, seed=2)
        rows += _sweep("disat", knn_search_sat, sat, queries, metric, bf,
                       repeat=repeat)

    # headline: hilbert/hyperbolic n_dist ratio per (engine, metric, k)
    # at B=8 — must be <= 1 on every four-point cell (hilbert excludes a
    # superset at every decision; the paper's claim carried to k-NN)
    summary = {}
    for r in rows:
        if r["mechanism"] != "hilbert" or r["frontier"] != 8:
            continue
        hyp = next(x for x in rows if x["engine"] == r["engine"]
                   and x["metric"] == r["metric"] and x["k"] == r["k"]
                   and x["mechanism"] == "hyperbolic"
                   and x["frontier"] == 8)
        cell = f"{r['engine']}/{r['metric']}/k={r['k']}"
        ratio = r["n_dist_mean"] / max(hyp["n_dist_mean"], 1e-9)
        summary[cell] = {
            "hilbert_n_dist": r["n_dist_mean"],
            "hyperbolic_n_dist": hyp["n_dist_mean"],
            "ratio": round(ratio, 4),
        }
        assert ratio <= 1.0 + 1e-9, \
            f"{cell}: hilbert n_dist EXCEEDS hyperbolic ({ratio:.4f})"
        print(f"{cell}: hilbert/hyperbolic n_dist = {ratio:.3f}")

    result = {
        "bench": "knn_cost",
        "n": n, "nq": nq, "dim": 8, "repeat": repeat,
        "ks": list(KS), "widths": list(WIDTHS),
        "device": jax.devices()[0].platform,
        "python": platform.python_version(),
        "jax": jax.__version__,
        "rows": rows,
        "summary": summary,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {json_path}")
    return result


if __name__ == "__main__":
    main()
