"""Paper Table 4 / Fig 12: GHT + MHT query cost (mean distance
evaluations per query, % of n) under Hyperbolic vs Hilbert exclusion.

Same index, same queries — only the exclusion predicate changes.
Correctness (§6.5) is asserted in-line: all four mechanisms must return
identical result sets (vs brute force).

Paper validation (n=10^6): euc_10 GHT 1.19% -> 0.68%, MHT 1.00% ->
0.48% at t1; the RATIOS are the reproduction target at smaller n.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (SPACES, check_vs_oracle, make_space,
                               thresholds_for)
from repro.core import bruteforce
from repro.core.tree import build_ght, build_mht, search_binary_tree

PAPER_RATIOS = {  # space -> (ght t1 hil/hyp, mht t1 hil/hyp)
    "euc_10": (0.68 / 1.19, 0.48 / 1.00),
    "euc_14": (6.25 / 9.92, 4.47 / 7.67),
    "jsd_10": (0.90 / 1.50, 0.68 / 1.35),
    "tri_10": (1.11 / 1.95, 0.84 / 1.66),
}


def run(n: int = 32768, nq: int = 128, dims=(6, 10, 14), tns=(1, 16),
        leaf_size: int = 16, seed: int = 0, check: bool = True):
    rows = []
    for metric_name, short in SPACES:
        for d in dims:
            data, queries = make_space(metric_name, d, n, nq, seed)
            ts = thresholds_for(metric_name, data, queries)
            trees = {
                "ght": build_ght(data, metric_name, leaf_size=leaf_size,
                                 seed=seed + 1),
                "mht": build_mht(data, metric_name, leaf_size=leaf_size,
                                 seed=seed + 1),
            }
            for tn in tns:
                t = ts[tn]
                ref_sets = None
                if check:
                    _, ref_sets = bruteforce.range_search(
                        data, queries, t, metric_name=metric_name)
                row = {"space": f"{short}_{d}", "t": f"t{tn}"}
                for kind, tree in trees.items():
                    mech_sets = {}
                    for mech in ("hyperbolic", "hilbert"):
                        st = search_binary_tree(
                            tree, queries, t, metric_name=metric_name,
                            mechanism=mech, r_cap=512)
                        mech_sets[mech] = st.result_sets()
                        if check:
                            check_vs_oracle(
                                data, queries, t, mech_sets[mech],
                                ref_sets,
                                context=f"{short}_{d}/{kind}/{mech}")
                        nd = float(np.mean(np.asarray(st.n_dist)))
                        row[f"{kind}_{mech[:3]}"] = round(100 * nd / n, 3)
                    assert mech_sets["hyperbolic"] == mech_sets["hilbert"]
                row["ght_ratio"] = round(
                    row["ght_hil"] / max(row["ght_hyp"], 1e-9), 3)
                row["mht_ratio"] = round(
                    row["mht_hil"] / max(row["mht_hyp"], 1e-9), 3)
                rows.append(row)
    return rows


def main(argv=None):
    print("table4_ght_mht_cost (mean distance evals per query, % of n)")
    print("space,t,ght_hyp,ght_hil,mht_hyp,mht_hil,ght_ratio,mht_ratio")
    for r in run():
        print(f"{r['space']},{r['t']},{r['ght_hyp']},{r['ght_hil']},"
              f"{r['mht_hyp']},{r['mht_hil']},{r['ght_ratio']},"
              f"{r['mht_ratio']}")


if __name__ == "__main__":
    main()
