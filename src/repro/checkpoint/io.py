"""Sharded checkpointing with atomic commit + async writer.

Layout:  <dir>/step_<N>/   arrays.npz  manifest.json
Commit protocol: write into  <dir>/tmp_step_<N>  then os.rename — a
preemption mid-save can never corrupt the newest complete checkpoint
(restore only ever reads committed step_* dirs).

Elastic restore: arrays are saved UNSHARDED-logical (full value per
leaf); ``restore_checkpoint(..., shardings=...)`` device_puts onto ANY
mesh, so a job can restart on a different topology (DESIGN.md §5).  At
real 1000-node scale each host would write only its slice (manifest
already records per-leaf specs to support it); full-value npz keeps this
container's implementation honest and testable.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return flat


def _tree_like(tree, flat: dict[str, np.ndarray]):
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    treedef = jax.tree_util.tree_structure(tree)
    return jax.tree_util.tree_unflatten(treedef, [flat[p] for p in paths])


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k.replace("/", "__SL__"): v for k, v in flat.items()})
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):                 # idempotent re-save
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any, *, step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (a pytree of NamedSharding — ANY mesh: elastic)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k.replace("__SL__", "/"): z[k] for k in z.files}
    tree = _tree_like(like, flat)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, step, manifest.get("extra", {})


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training (one in-flight save)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             block: bool = False):
        self.wait()                            # one in-flight save max
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot

        def _do():
            save_checkpoint(self.ckpt_dir, step, host_tree, extra)
            self.last_saved = step

        self._thread = threading.Thread(target=_do, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
