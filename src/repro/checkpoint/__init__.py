from repro.checkpoint.io import (  # noqa: F401
    save_checkpoint, restore_checkpoint, latest_step, AsyncCheckpointer)
