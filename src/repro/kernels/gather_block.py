"""Pallas TPU kernels: gather-block distances for frontier traversal.

The frontier-batched engines (DESIGN.md §3) gather, per query lane, one
tile of L candidate points — pivots, children and leaf buckets of every
node in the lane's frontier — and need d(q_i, tile_i[l]) for all (i, l).
That is the *lane-local* shape (Q, L, d) -> (Q, L), distinct from the
dense (Q, N) pairwise family in ``pairwise.py``: each lane contracts
against its own points, so the contraction is a batched GEMV, not a
GEMM.

Two kernel families, mirroring pairwise.py:

  * MXU family (euclidean / sqeuclidean / cosine): the cross term is a
    batched dot ``q[i] . pts[i, l]`` via ``dot_general`` with a batch
    dimension; the |x|^2 / |x| terms come from the per-tree squared-norm
    cache (``flat.py norm_sq``) gathered alongside the tile, so the
    kernel never re-reduces the d axis for norms.

  * VPU family (jsd / triangular): elementwise O(Q*L*d) accumulation
    over the (BQ, BL, d) broadcast, VMEM-resident.

Grid is (Q tiles, L tiles); the d axis stays whole per block (metric-
search dimensionalities are small, padded to a 128 lane multiple).  All
inputs are zero-padded by the wrapper: h(0)=0, 0/0 guarded, zero rows
produce garbage *distances* only in padded slots, which every caller
masks (traversal masks invalid frontier slots anyway).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pairwise import _h

_EPS = 1e-12

# (BQ, BL): MXU family rides the batched-dot path with full 128 lanes;
# VPU family keeps the (BQ, BL, d) broadcast under ~1 MiB of VMEM.
_BLOCKS = {
    "euclidean": (8, 128),
    "sqeuclidean": (8, 128),
    "cosine_prenorm": (8, 128),
    "jsd": (8, 32),
    "triangular": (8, 32),
}

SUPPORTED = frozenset(_BLOCKS)


def _batched_dot(q, pts):
    """q (BQ, d) . pts (BQ, BL, d) -> (BQ, BL) lane-local contraction."""
    return jax.lax.dot_general(
        q, pts, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def _gather_l2_kernel(q_ref, pts_ref, pp_ref, o_ref, *, squared: bool):
    """|q|^2 + |p|^2 - 2 q.p with |p|^2 from the gathered norm cache."""
    q = q_ref[...].astype(jnp.float32)            # (BQ, d)
    pts = pts_ref[...].astype(jnp.float32)        # (BQ, BL, d)
    qq = jnp.sum(q * q, axis=-1)[:, None]
    d2 = jnp.maximum(qq + pp_ref[...] - 2.0 * _batched_dot(q, pts), 0.0)
    o_ref[...] = d2 if squared else jnp.sqrt(d2)


def _gather_cos_kernel(q_ref, pts_ref, pp_ref, o_ref):
    """sqrt(1 - cos) on pre-normalised q rows; tile rows are normalised
    in-kernel by the cached norms (one rsqrt per point, no d-reduction)."""
    q = q_ref[...].astype(jnp.float32)
    pts = pts_ref[...].astype(jnp.float32)
    inv = 1.0 / jnp.maximum(jnp.sqrt(pp_ref[...]), _EPS)
    sim = jnp.clip(_batched_dot(q, pts) * inv, -1.0, 1.0)
    o_ref[...] = jnp.sqrt(jnp.maximum(1.0 - sim, 0.0))


def _gather_jsd_kernel(q_ref, pts_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)            # (BQ, d)
    pts = pts_ref[...].astype(jnp.float32)        # (BQ, BL, d)
    hq = jnp.sum(_h(q), axis=-1)[:, None]
    hp = jnp.sum(_h(pts), axis=-1)
    hqp = jnp.sum(_h(q[:, None, :] + pts), axis=-1)
    jsdiv = 1.0 - 0.5 * (hq + hp - hqp)
    o_ref[...] = jnp.sqrt(jnp.maximum(jsdiv, 0.0))


def _gather_triangular_kernel(q_ref, pts_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    pts = pts_ref[...].astype(jnp.float32)
    diff2 = (q[:, None, :] - pts) ** 2
    den = q[:, None, :] + pts
    terms = jnp.where(den > _EPS, diff2 / jnp.maximum(den, _EPS), 0.0)
    o_ref[...] = jnp.sqrt(jnp.maximum(jnp.sum(terms, axis=-1), 0.0))


_MXU_KERNELS = {
    "euclidean": functools.partial(_gather_l2_kernel, squared=False),
    "sqeuclidean": functools.partial(_gather_l2_kernel, squared=True),
    "cosine_prenorm": _gather_cos_kernel,
}

_VPU_KERNELS = {
    "jsd": _gather_jsd_kernel,
    "triangular": _gather_triangular_kernel,
}


def _pad_axis(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    p = (-a.shape[axis]) % mult
    if not p:
        return a
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, p)
    return jnp.pad(a, pads)


def gather_block_pallas(q: jnp.ndarray, pts: jnp.ndarray,
                        pts_norm_sq: jnp.ndarray | None, kind: str, *,
                        interpret: bool = True) -> jnp.ndarray:
    """Lane-gathered block distances.  q (Q, d), pts (Q, L, d) -> (Q, L).

    ``pts_norm_sq`` (Q, L): cached |p|^2 for the MXU family (gathered
    from the tree's ``norm_sq``); computed on the fly when None.  For
    ``cosine_prenorm`` the q rows must already be unit-normalised, and
    pts_norm_sq must hold the UN-normalised squared norms.

    ``interpret=True`` runs the kernel body in Python on CPU (validation
    mode for this container); on TPU pass interpret=False.
    """
    bq, bl = _BLOCKS[kind]
    nq, l_in = q.shape[0], pts.shape[1]
    qp = _pad_axis(_pad_axis(q.astype(jnp.float32), 0, bq), 1, 128)
    pp = _pad_axis(_pad_axis(
        _pad_axis(pts.astype(jnp.float32), 0, bq), 1, bl), 2, 128)
    m, d = qp.shape
    l = pp.shape[1]
    grid = (m // bq, l // bl)

    if kind in _MXU_KERNELS:
        if pts_norm_sq is None:
            pts_norm_sq = jnp.sum(pts.astype(jnp.float32) ** 2, axis=-1)
        np_ = _pad_axis(_pad_axis(
            pts_norm_sq.astype(jnp.float32), 0, bq), 1, bl)
        return pl.pallas_call(
            _MXU_KERNELS[kind],
            grid=grid,
            in_specs=[
                pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
                pl.BlockSpec((bq, bl, d), lambda i, j: (i, j, 0)),
                pl.BlockSpec((bq, bl), lambda i, j: (i, j)),
            ],
            out_specs=pl.BlockSpec((bq, bl), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, l), jnp.float32),
            interpret=interpret,
        )(qp, pp, np_)[:nq, :l_in]

    return pl.pallas_call(
        _VPU_KERNELS[kind],
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, bl, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bl), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, l), jnp.float32),
        interpret=interpret,
    )(qp, pp)[:nq, :l_in]
