"""Pallas TPU kernels: tiled pairwise distances (the paper's hot spot).

TPU adaptation (DESIGN.md §2): the paper's per-object distance evaluation
becomes dense tile evaluation.  Two kernel families:

  * MXU family (euclidean / cosine): distance reduces to a matmul plus
    rank-1 row/col norm terms -> systolic-array bound.  Grid (i, j, k)
    over (Q tiles, X tiles, D chunks); f32 accumulation in the output
    tile, which Pallas keeps resident in VMEM across the k loop because
    its index_map ignores k.

  * VPU family (jsd / triangular): the cross term h(q+x) / (q-x)^2/(q+x)
    cannot factor into a matmul; it is an elementwise O(Q*N*D) loop.
    Same grid; the (BM, BN, BK) broadcast lives only in VMEM/VREGs.

Block sizes are MXU/VREG aligned (multiples of 8x128 lanes).  All inputs
are zero-padded by the ops.py wrapper; padding is harmless for every
family (h(0)=0; 0/0 guarded; zero rows add zero to dots/norms).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12


# ---------------------------------------------------------------------------
# MXU family: squared-L2 / dot accumulation
# ---------------------------------------------------------------------------

def _l2_kernel(q_ref, x_ref, o_ref, *, nk: int, squared: bool):
    """Accumulate |q|^2 + |x|^2 - 2 q.x over D chunks; sqrt on last chunk."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)          # (BM, BK)
    x = x_ref[...].astype(jnp.float32)          # (BN, BK)
    acc = o_ref[...]
    acc += jnp.sum(q * q, -1)[:, None]
    acc += jnp.sum(x * x, -1)[None, :]
    acc += -2.0 * jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] = acc

    @pl.when(k == nk - 1)
    def _finish():
        d2 = jnp.maximum(o_ref[...], 0.0)
        o_ref[...] = d2 if squared else jnp.sqrt(d2)


def _dot_kernel(q_ref, x_ref, o_ref, *, nk: int):
    """Accumulate q.x over D chunks; finish as sqrt(1 - dot) (cosine on
    pre-normalised rows)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        sim = jnp.clip(o_ref[...], -1.0, 1.0)
        o_ref[...] = jnp.sqrt(jnp.maximum(1.0 - sim, 0.0))


# ---------------------------------------------------------------------------
# VPU family: f-divergence accumulation
# ---------------------------------------------------------------------------

def _h(v):
    safe = jnp.where(v > _EPS, v, 1.0)
    return jnp.where(v > _EPS, -safe * jnp.log2(safe), 0.0)


def _jsd_kernel(q_ref, x_ref, o_ref, *, nk: int):
    """acc += sum_k h(q)+h(x)-h(q+x); finish sqrt(max(1 - acc/2, 0))."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)           # (BM, BK)
    x = x_ref[...].astype(jnp.float32)           # (BN, BK)
    hq = jnp.sum(_h(q), -1)[:, None]
    hx = jnp.sum(_h(x), -1)[None, :]
    hqx = jnp.sum(_h(q[:, None, :] + x[None, :, :]), -1)
    o_ref[...] += hq + hx - hqx

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = jnp.sqrt(jnp.maximum(1.0 - 0.5 * o_ref[...], 0.0))


def _triangular_kernel(q_ref, x_ref, o_ref, *, nk: int):
    """acc += sum_k (q-x)^2/(q+x); finish sqrt."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    diff2 = (q[:, None, :] - x[None, :, :]) ** 2
    den = q[:, None, :] + x[None, :, :]
    o_ref[...] += jnp.sum(
        jnp.where(den > _EPS, diff2 / jnp.maximum(den, _EPS), 0.0), -1)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = jnp.sqrt(jnp.maximum(o_ref[...], 0.0))


_KERNELS = {
    "euclidean": functools.partial(_l2_kernel, squared=False),
    "sqeuclidean": functools.partial(_l2_kernel, squared=True),
    "cosine_prenorm": _dot_kernel,
    "jsd": _jsd_kernel,
    "triangular": _triangular_kernel,
}

# (BM, BN, BK): MXU family uses 128-square tiles; VPU family keeps the
# (BM, BN, BK) broadcast under ~2 MiB of VMEM.
_BLOCKS = {
    "euclidean": (128, 128, 128),
    "sqeuclidean": (128, 128, 128),
    "cosine_prenorm": (128, 128, 128),
    "jsd": (32, 32, 128),
    "triangular": (32, 32, 128),
}


def pairwise_pallas(q: jnp.ndarray, x: jnp.ndarray, kind: str, *,
                    interpret: bool = True) -> jnp.ndarray:
    """Tiled pairwise distances.  q: (Q, D), x: (N, D) -> (Q, N) f32.

    Inputs MUST already be padded to block multiples (ops.py does this).
    ``interpret=True`` executes the kernel body in Python on CPU — the
    validation mode for this container; on TPU pass interpret=False.
    """
    kernel = _KERNELS[kind]
    bm, bn, bk = _BLOCKS[kind]
    m, d = q.shape
    n, d2 = x.shape
    assert d == d2, (q.shape, x.shape)
    assert m % bm == 0 and n % bn == 0 and d % bk == 0, \
        f"pad to blocks first: {(m, n, d)} vs {(bm, bn, bk)}"
    nk = d // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(q, x)
