"""jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, cosine pre-normalisation, dtype
management, and the CPU(interpret) / TPU(compiled) switch.  On this
container only interpret mode runs; on TPU set
``repro.kernels.ops.INTERPRET = False`` (or the REPRO_PALLAS_COMPILED=1
env var) to lower for real.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.pairwise import pairwise_pallas, _BLOCKS
from repro.kernels.exclusion_step import exclusion_margins_pallas

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILED", "0") != "1"

_EPS = 1e-12


def _pad_to(a: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def _pairwise(q, x, *, kind: str, interpret: bool):
    bm, bn, bk = _BLOCKS[kind]
    mq, nx = q.shape[0], x.shape[0]
    if kind == "cosine_prenorm":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), _EPS)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), _EPS)
    qp = _pad_to(q, bm, bk)
    xp = _pad_to(x, bn, bk)
    out = pairwise_pallas(qp, xp, kind, interpret=interpret)
    return out[:mq, :nx]


# metric name -> kernel kind; keys are the metrics the pairwise kernel
# family supports (dispatch layers consult SUPPORTED, not a copy)
_KIND_FOR = {"euclidean": "euclidean", "sqeuclidean": "sqeuclidean",
             "cosine": "cosine_prenorm", "jsd": "jsd",
             "triangular": "triangular"}
SUPPORTED = frozenset(_KIND_FOR)


def pairwise_distance(q, x, metric_name: str, *,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Kernel-backed pairwise distances.  metric_name in SUPPORTED."""
    kind = _KIND_FOR[metric_name]
    itp = INTERPRET if interpret is None else interpret
    return _pairwise(jnp.asarray(q, jnp.float32), jnp.asarray(x, jnp.float32),
                     kind=kind, interpret=itp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _excl(q, p1, p2, d12, *, interpret: bool):
    bq, bp, bk = 128, 128, 128
    mq, pp = q.shape[0], p1.shape[0]
    qp = _pad_to(q, bq, bk)
    p1p = _pad_to(p1, bp, bk)
    p2p = _pad_to(p2, bp, bk)
    dp = jnp.pad(d12, (0, (-pp) % bp))
    hyp, hil = exclusion_margins_pallas(qp, p1p, p2p, dp,
                                        interpret=interpret)
    return hyp[:mq, :pp], hil[:mq, :pp]


def exclusion_margins(q, p1, p2, d12, *, interpret: bool | None = None):
    """Fused Euclidean partition margins: returns (hyperbolic, hilbert),
    each (Q, P);  margin > t  =>  the p1 side of pair j is excludable."""
    itp = INTERPRET if interpret is None else interpret
    return _excl(jnp.asarray(q, jnp.float32), jnp.asarray(p1, jnp.float32),
                 jnp.asarray(p2, jnp.float32), jnp.asarray(d12, jnp.float32),
                 interpret=itp)
