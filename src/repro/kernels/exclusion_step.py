"""Fused partition-exclusion Pallas kernel.

One pass computes, for a tile of queries x a tile of pivot PAIRS:
  d1 = ||q - p1||,  d2 = ||q - p2||,
  hyperbolic margin (d1 - d2)/2,
  hilbert margin   (d1^2 - d2^2)/(2 d12)   (guarded for d12 ~ 0)
without materialising d1/d2 to HBM — the whole node-level partition
decision of a hyperplane index in a single VMEM-resident tile.  This is
the kernel behind the exclusion-power benchmark (paper Figs 8/9) and the
bulk-partition phase of batched index builds.

Grid (i, j, k): query tiles x pair tiles x D chunks; two f32 accumulators
(d1^2, d2^2) live in VMEM scratch; margins are emitted on the last chunk.
Euclidean only (the MXU-friendly case the paper's experiments centre on).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-12


def _excl_kernel(q_ref, p1_ref, p2_ref, d12_ref, hyp_ref, hil_ref,
                 acc1_ref, acc2_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    q = q_ref[...].astype(jnp.float32)       # (BQ, BK)
    p1 = p1_ref[...].astype(jnp.float32)     # (BP, BK)
    p2 = p2_ref[...].astype(jnp.float32)     # (BP, BK)

    def sq_acc(p, acc_ref):
        acc = acc_ref[...]
        acc += jnp.sum(q * q, -1)[:, None]
        acc += jnp.sum(p * p, -1)[None, :]
        acc += -2.0 * jax.lax.dot_general(
            q, p, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc

    sq_acc(p1, acc1_ref)
    sq_acc(p2, acc2_ref)

    @pl.when(k == nk - 1)
    def _finish():
        d1sq = jnp.maximum(acc1_ref[...], 0.0)
        d2sq = jnp.maximum(acc2_ref[...], 0.0)
        d1 = jnp.sqrt(d1sq)
        d2 = jnp.sqrt(d2sq)
        d12 = d12_ref[...].astype(jnp.float32)[None, :]    # (1, BP)
        hyp_ref[...] = 0.5 * (d1 - d2)
        hil_ref[...] = jnp.where(
            d12 > 1e-9, (d1sq - d2sq) / (2.0 * jnp.maximum(d12, _EPS)), 0.0)


def exclusion_margins_pallas(q: jnp.ndarray, p1: jnp.ndarray,
                             p2: jnp.ndarray, d12: jnp.ndarray, *,
                             interpret: bool = True
                             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """q: (Q, D); p1, p2: (P, D); d12: (P,) -> (hyp, hil), each (Q, P) f32.

    Inputs must be padded to block multiples (ops.py wrapper handles it).
    """
    bq, bp, bk = 128, 128, 128
    m, d = q.shape
    p, d2 = p1.shape
    assert p1.shape == p2.shape and d12.shape == (p,) and d == d2
    assert m % bq == 0 and p % bp == 0 and d % bk == 0, (m, p, d)
    nk = d // bk
    grid = (m // bq, p // bp, nk)
    return pl.pallas_call(
        functools.partial(_excl_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bp, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bp, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bp,), lambda i, j, k: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, bp), lambda i, j, k: (i, j)),
            pl.BlockSpec((bq, bp), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, p), jnp.float32),
            jax.ShapeDtypeStruct((m, p), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, bp), jnp.float32),
            pltpu.VMEM((bq, bp), jnp.float32),
        ],
        interpret=interpret,
    )(q, p1, p2, d12)
