"""Pure-jnp oracles for the Pallas kernels (the correctness references).

Every kernel in this package must match its oracle to tolerance across a
shape/dtype sweep (tests/test_kernels_*.py).
"""

from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray

_EPS = 1e-12


def pairwise_l2_ref(q: Array, x: Array, *, squared: bool = False) -> Array:
    """(Q, D), (N, D) -> (Q, N) Euclidean distances, f32 accumulation."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qq = jnp.sum(q * q, -1)[:, None]
    xx = jnp.sum(x * x, -1)[None, :]
    d2 = jnp.maximum(qq + xx - 2.0 * (q @ x.T), 0.0)
    return d2 if squared else jnp.sqrt(d2)


def pairwise_cosine_ref(q: Array, x: Array) -> Array:
    """sqrt(1 - cos) on raw vectors (wrapper normalises)."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), _EPS)
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), _EPS)
    sim = jnp.clip(qn @ xn.T, -1.0, 1.0)
    return jnp.sqrt(jnp.maximum(1.0 - sim, 0.0))


def _h(v: Array) -> Array:
    safe = jnp.where(v > _EPS, v, 1.0)
    return jnp.where(v > _EPS, -safe * jnp.log2(safe), 0.0)


def pairwise_jsd_ref(q: Array, x: Array) -> Array:
    """sqrt(JSD) over probability rows."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    hq = jnp.sum(_h(q), -1)[:, None]
    hx = jnp.sum(_h(x), -1)[None, :]
    hqx = jnp.sum(_h(q[:, None, :] + x[None, :, :]), -1)
    return jnp.sqrt(jnp.maximum(1.0 - 0.5 * (hq + hx - hqx), 0.0))


def pairwise_triangular_ref(q: Array, x: Array) -> Array:
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    diff2 = (q[:, None, :] - x[None, :, :]) ** 2
    den = q[:, None, :] + x[None, :, :]
    terms = jnp.where(den > _EPS, diff2 / jnp.maximum(den, _EPS), 0.0)
    return jnp.sqrt(jnp.maximum(jnp.sum(terms, -1), 0.0))


def exclusion_margins_ref(q: Array, p1: Array, p2: Array, d12: Array
                          ) -> tuple[Array, Array]:
    """Fused partition-step oracle (Euclidean).

    q: (Q, D); p1, p2: (P, D) pivot pairs; d12: (P,) build-time pivot
    distances.  Returns (hyperbolic_margin, hilbert_margin), each (Q, P);
    margin > t  =>  the p1 side of pair j is excludable for query i.
    """
    d1 = pairwise_l2_ref(q, p1)
    d2 = pairwise_l2_ref(q, p2)
    m_hyp = 0.5 * (d1 - d2)
    safe = d12[None, :] > 1e-9
    m_hil = jnp.where(
        safe, (d1 * d1 - d2 * d2) / (2.0 * jnp.maximum(d12[None, :], _EPS)),
        0.0)
    return m_hyp, m_hil
