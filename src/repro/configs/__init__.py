from repro.configs.base import ARCH_IDS, CellProgram, all_cells, get  # noqa: F401
