"""granite-moe-3b-a800m  [moe] 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8  [hf:ibm-granite/granite-3.0-1b-a400m]

40 experts do not divide the model axis (16): experts are
TENSOR-parallel (d_ff split over "model"), not expert-parallel —
DESIGN.md §4, no padding/waste."""

from repro.configs import lm_common as C
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
ARCH = "granite-moe-3b-a800m"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH, n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155, act="silu",
        moe=MoEConfig(n_experts=40, top_k=8, d_model=1536, d_ff=512,
                      group_size=32768))


def reduced_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        name=ARCH + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=32, vocab=512, act="silu", attn_block=32,
        moe=MoEConfig(n_experts=5, top_k=2, d_model=64, d_ff=32,
                      group_size=64),
        dtype=jnp.float32)


def shapes():
    return C.SHAPES


def cell(shape_name, mesh):
    return C.cell(ARCH, full_config(), shape_name, mesh)


def smoke(key=None):
    return C.smoke(reduced_config(), key)
