"""metric-search — the PAPER's own workload registered as an arch.

Shapes mirror the paper's experimental spaces (§6.1): batched range
queries over n=10^6 points in R^d.  The dry-run cell lowers the exact
blocked-scan serving step (the MXU tile path whose tile count Hilbert
Exclusion reduces); the tree engines themselves run in the benchmarks
(they are host+device hybrid and are exercised by tests, not lowered at
the 512-chip mesh).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import CellProgram
from repro.core import metrics as metrics_lib
from repro.sharding import specs as S

FAMILY = "metric"
ARCH = "metric-search"

SHAPES = {
    "euc10_1m": {"n": 1000000, "dim": 10, "n_queries": 1024,
                 "metric": "euclidean", "kind": "serve"},
    "euc14_1m": {"n": 1000000, "dim": 14, "n_queries": 1024,
                 "metric": "euclidean", "kind": "serve"},
    "jsd10_1m": {"n": 1000000, "dim": 10, "n_queries": 1024,
                 "metric": "jsd", "kind": "serve"},
}


def full_config():
    return {"shapes": SHAPES}


def reduced_config():
    return {"n": 2048, "dim": 8, "n_queries": 16, "metric": "euclidean"}


def shapes():
    return SHAPES


def cell(shape_name, mesh, *, topk_impl: str = "shard_map") -> CellProgram:
    shp = SHAPES[shape_name]
    metric = metrics_lib.get(shp["metric"])
    b = S.batch_axes(mesh)
    baxes = b if isinstance(b, tuple) else (b,)
    n_data_shards = (mesh.shape["data"] * mesh.shape.get("pod", 1))
    shard_n = shp["n"] // n_data_shards

    def serve_naive(data, queries, t):
        # §Perf baseline: lax.top_k over the data-sharded candidate axis
        # makes GSPMD replicate the FULL (Q, N) distance matrix (4.1 GB
        # all-gathers measured on the 16x16 mesh)
        d = metric.pairwise(queries, data)
        counts = jnp.sum(d <= t, axis=1, dtype=jnp.int32)
        neg, idx = jax.lax.top_k(-d, 16)
        return counts, -neg, idx

    def serve_sharded(data, queries, t):
        # §Perf optimized: explicit locality via shard_map — per-shard
        # top-k, then an all-gather of only (Q_loc, 16*shards) candidates
        from jax.experimental.shard_map import shard_map
        from functools import partial

        @partial(shard_map, mesh=mesh,
                 in_specs=(P(b, None), P("model", None), P()),
                 out_specs=(P("model"), P("model", None),
                            P("model", None)),
                 check_rep=False)
        def _run(data_l, queries_l, tt):
            d = metric.pairwise(queries_l, data_l)   # (Qloc, Nloc)
            cnt = jnp.sum(d <= tt, axis=1, dtype=jnp.int32)
            for ax in baxes:
                cnt = jax.lax.psum(cnt, ax)
            lneg, lidx = jax.lax.top_k(-d, 16)       # local candidates
            shard_id = jax.lax.axis_index(baxes[-1])
            if len(baxes) == 2:
                shard_id = shard_id + mesh.shape["data"] \
                    * jax.lax.axis_index(baxes[0])
            gidx = lidx + shard_id * shard_n
            negs = lneg
            for ax in baxes:
                negs = jax.lax.all_gather(negs, ax, axis=1, tiled=True)
                gidx = jax.lax.all_gather(gidx, ax, axis=1, tiled=True)
            neg, sel = jax.lax.top_k(negs, 16)
            idx = jnp.take_along_axis(gidx, sel, axis=1)
            return cnt, -neg, idx

        return _run(data, queries, t)

    fn = serve_naive if topk_impl == "naive" else serve_sharded
    inputs = (jax.ShapeDtypeStruct((shp["n"], shp["dim"]), jnp.float32),
              jax.ShapeDtypeStruct((shp["n_queries"], shp["dim"]),
                                   jnp.float32),
              jax.ShapeDtypeStruct((), jnp.float32))
    in_specs = (P(b, None), P("model", None), P())
    flops = 2.0 * shp["n"] * shp["n_queries"] * shp["dim"]
    return CellProgram(ARCH, shape_name, "serve", fn, inputs,
                       in_specs, out_specs=(P("model"), P("model", None),
                                            P("model", None)),
                       model_flops_per_step=flops)


def smoke(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    cfg = reduced_config()
    data = jax.random.uniform(key, (cfg["n"], cfg["dim"]))
    queries = jax.random.uniform(jax.random.PRNGKey(1),
                                 (cfg["n_queries"], cfg["dim"]))
    metric = metrics_lib.get(cfg["metric"])
    d = metric.pairwise(queries, data)
    counts = jnp.sum(d <= 0.3, axis=1)
    return {"counts": counts, "d": d}
