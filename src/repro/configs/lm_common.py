"""Shared cell/smoke machinery for the 5 LM transformer archs.

Shapes (assignment):
  train_4k     seq 4096   global_batch 256    -> train_step
  prefill_32k  seq 32768  global_batch 32     -> serve (prefill)
  decode_32k   seq 32768  global_batch 128    -> serve (1-token decode)
  long_500k    seq 524288 global_batch 1      -> serve (1-token decode,
                                                 sequence-sharded cache)

MODEL_FLOPS: train = 6*N*D (N = active params, D = tokens) + attention
12*B*H*S^2*dh (counted separately since 6ND excludes it); serve decode =
2*N per token + attention 4*S*H*dh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import CellProgram
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.sharding import specs as S

SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "serve"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "serve",
                   "decode": True},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "serve",
                  "decode": True},
}

_OPT = AdamWConfig()


def abstract_params(cfg: T.TransformerConfig):
    return jax.eval_shape(
        lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))


def abstract_opt(params_shape):
    return jax.eval_shape(adamw_init, params_shape)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_train_fn(cfg: T.TransformerConfig):
    def train_step(params, opt_state, tokens, targets):
        def loss(p):
            return T.loss_fn(p, cfg, tokens, targets)
        l, grads = jax.value_and_grad(loss)(params)
        params, opt_state, metrics = adamw_update(
            params, grads, opt_state, _OPT)
        return params, opt_state, l
    return train_step


def make_prefill_fn(cfg: T.TransformerConfig):
    def serve_prefill(params, tokens):
        return T.prefill(params, cfg, tokens)
    return serve_prefill


def make_decode_fn(cfg: T.TransformerConfig):
    def serve_decode(params, cache, token):
        return T.decode_step(params, cfg, cache, token)
    return serve_decode


def model_flops(cfg: T.TransformerConfig, shape: dict) -> float:
    s, b = shape["seq_len"], shape["global_batch"]
    n_act = cfg.n_active_params
    dh, hq = cfg.head_dim, cfg.n_heads
    if shape["kind"] == "train":
        tokens = s * b
        dense = 6.0 * n_act * tokens
        attn = 12.0 * b * hq * s * s * dh * cfg.n_layers  # fwd+bwd qk+av
        return dense + attn
    if shape.get("decode"):
        # decode: 2N per token + 4*S*H*dh attention per token
        return (2.0 * n_act + 4.0 * s * hq * dh * cfg.n_layers) * b
    # prefill: fwd-only
    tokens = s * b
    return 2.0 * n_act * tokens + 4.0 * b * hq * s * s * dh * cfg.n_layers


def _with_ctx(fn, mesh, **flags):
    """Trace ``fn`` under the mesh context so model-level
    with_sharding_constraint anchors resolve (DESIGN.md §5)."""
    def wrapped(*args):
        with S.mesh_context(mesh, **flags):
            return fn(*args)
    return wrapped


def cell(arch: str, cfg: T.TransformerConfig, shape_name: str, mesh
         ) -> CellProgram:
    shp = SHAPES[shape_name]
    b, s = shp["global_batch"], shp["seq_len"]
    params = abstract_params(cfg)
    pspecs = S.transformer_param_specs(params, cfg, mesh)
    baxes = S.batch_axes(mesh)
    flags = {}
    if cfg.moe is not None:
        flags["moe_ep"] = cfg.moe.n_experts % mesh.shape["model"] == 0
    if shape_name == "long_500k":
        flags["long_context"] = True

    if shape_name == "train_4k":
        opt = abstract_opt(params)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        fn = _with_ctx(make_train_fn(cfg), mesh, **flags)
        inputs = (params, opt,
                  _sds((b, s), jnp.int32), _sds((b, s), jnp.int32))
        in_specs = (pspecs, ospecs, P(baxes, None), P(baxes, None))
        return CellProgram(arch, shape_name, "train", fn, inputs, in_specs,
                           out_specs=(pspecs, ospecs, P()),
                           donate=(0, 1),
                           model_flops_per_step=model_flops(cfg, shp))

    if shape_name == "prefill_32k":
        fn = _with_ctx(make_prefill_fn(cfg), mesh, **flags)
        inputs = (params, _sds((b, s), jnp.int32))
        cache_specs = S.transformer_cache_specs(mesh, long_context=False)
        kv = cache_specs["k"]
        in_specs = (pspecs, P(baxes, None))
        out_specs = (P(baxes, "model"),
                     {"k": kv, "v": kv, "len": P()})
        return CellProgram(arch, shape_name, "serve", fn, inputs, in_specs,
                           out_specs=out_specs,
                           model_flops_per_step=model_flops(cfg, shp))

    # decode cells
    long = shape_name == "long_500k"
    cache_specs = S.transformer_cache_specs(mesh, long_context=long)
    cache = {
        "k": _sds((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.head_dim),
                  cfg.dtype),
        "v": _sds((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.head_dim),
                  cfg.dtype),
        "len": _sds((), jnp.int32),
    }
    fn = _with_ctx(make_decode_fn(cfg), mesh, **flags)
    inputs = (params, cache, _sds((b,), jnp.int32))
    tok_spec = P() if long else P(baxes)
    in_specs = (pspecs, cache_specs, tok_spec)
    out_specs = (P(None if long else baxes, "model"), cache_specs)
    return CellProgram(arch, shape_name, "serve", fn, inputs, in_specs,
                       out_specs=out_specs,
                       model_flops_per_step=model_flops(cfg, shp))


# ---------------------------------------------------------------------------
# smoke machinery
# ---------------------------------------------------------------------------

def smoke(cfg_reduced: T.TransformerConfig, key=None):
    """One reduced train step + prefill + decode on CPU; returns dict of
    outputs for assertions."""
    key = key if key is not None else jax.random.PRNGKey(0)
    p = T.init_params(key, cfg_reduced)
    b, s = 2, 64
    toks = jax.random.randint(key, (b, s), 0, cfg_reduced.vocab)
    fn = make_train_fn(cfg_reduced)
    opt = adamw_init(p)
    p2, opt2, loss = jax.jit(fn)(p, opt, toks, toks)
    logits, cache = jax.jit(make_prefill_fn(cfg_reduced))(p, toks)
    dec_logits, cache2 = jax.jit(make_decode_fn(cfg_reduced))(
        p, cache, jnp.zeros((b,), jnp.int32))
    return {"loss": loss, "logits": logits, "dec_logits": dec_logits,
            "cache_len": cache2["len"]}
