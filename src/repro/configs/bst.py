"""bst  [recsys] Behaviour Sequence Transformer (Alibaba): embed_dim=32,
seq_len=20, 1 block, 8 heads, mlp=1024-512-256  [arXiv:1905.06874]

Item vocab 4M (Taobao-scale), plus user/context tables."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import recsys_common as C
from repro.configs.base import CellProgram
from repro.models import recsys as R
from repro.sharding import specs as S

FAMILY = "recsys"
ARCH = "bst"

VOCABS = (4000000, 1000000, 100000, 1000)   # items, users, shops, cates


def full_config() -> R.BSTConfig:
    return R.BSTConfig(
        name=ARCH, embed=R.EmbeddingSpec(VOCABS, 32), seq_len=20,
        n_heads=8, n_blocks=1, mlp=(1024, 512, 256))


def reduced_config() -> R.BSTConfig:
    return R.BSTConfig(
        name=ARCH + "-smoke", embed=R.EmbeddingSpec((512, 128), 16),
        seq_len=8, n_heads=4, n_blocks=1, mlp=(32, 16))


def shapes():
    return C.SHAPES


def _param_specs(params, mesh):
    def rule(path, leaf):
        if "table" in path:
            return P("model", None)
        if leaf.ndim == 2 and leaf.shape[0] % mesh.shape["model"] == 0 \
                and leaf.shape[0] >= 256:
            return P("model", None)
        return P()
    return jax.tree_util.tree_map_with_path(
        lambda p, l: rule(jax.tree_util.keystr(p), l), params)


def _flops(cfg: R.BSTConfig, batch: int) -> float:
    d, s = cfg.embed.dim, cfg.seq_len + 1
    attn = cfg.n_blocks * (4 * d * d * s + 2 * s * s * d * 2
                           + 8 * d * d * s)
    mlps = C.mlp_params(((s) * d,) + cfg.mlp + (1,))
    return 6.0 * batch * (attn + mlps)


def cell(shape_name, mesh) -> CellProgram:
    cfg = full_config()
    params = jax.eval_shape(lambda k: R.bst_init(k, cfg),
                            jax.random.PRNGKey(0))
    pspecs = _param_specs(params, mesh)
    b = S.batch_axes(mesh)
    shp = C.SHAPES[shape_name]

    def fwd(p, hist, tgt):
        return R.bst_forward(p, cfg, hist, tgt)

    if shape_name == "train_batch":
        bt = shp["batch"]

        def loss_of(p, hist, tgt, labels):
            return R.bce_loss(fwd(p, hist, tgt), labels)

        return C.make_train_cell(
            ARCH, params, pspecs, mesh, loss_of,
            (C.sds((bt, cfg.seq_len), jnp.int32), C.sds((bt,), jnp.int32),
             C.sds((bt,), jnp.float32)),
            (P(b, None), P(b), P(b)), _flops(cfg, bt) * 3)

    bt = shp["n_candidates"] if shape_name == "retrieval_cand" \
        else shp["batch"]
    return C.make_serve_cell(
        ARCH, shape_name, params, pspecs, fwd,
        (C.sds((bt, cfg.seq_len), jnp.int32), C.sds((bt,), jnp.int32)),
        (P(b, None), P(b)), _flops(cfg, bt), out_specs=P(b))


def smoke(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    cfg = reduced_config()
    p = R.bst_init(key, cfg)
    hist = jax.random.randint(key, (16, cfg.seq_len), 0, 512)
    tgt = jax.random.randint(key, (16,), 0, 512)
    labels = (jax.random.uniform(key, (16,)) < 0.3).astype(jnp.float32)
    logits = R.bst_forward(p, cfg, hist, tgt)
    loss = R.bce_loss(logits, labels)
    return {"logits": logits, "loss": loss}
