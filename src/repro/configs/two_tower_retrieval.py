"""two-tower-retrieval  [recsys] embed_dim=256, tower_mlp=1024-512-256,
dot interaction, sampled softmax  [RecSys'19 (YouTube)]

The `retrieval_cand` cell (1 query vs 10^6 candidates) is the PAPER's
exact workload: candidate embeddings live in the d_cos = sqrt(1-cos)
space (§5.5, Hilbert-embeddable), so serving can use either the batched
MXU dot-scan lowered here or the Hilbert-exclusion metric index
(examples/serve_retrieval.py runs both and checks identical results)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import recsys_common as C
from repro.configs.base import CellProgram
from repro.models import recsys as R
from repro.sharding import specs as S

FAMILY = "recsys"
ARCH = "two-tower-retrieval"

USER_VOCABS = (10000000, 100000, 10000, 1000, 100, 50, 20, 10)
ITEM_VOCABS = (1000000, 50000, 1000, 100)


def full_config() -> R.TwoTowerConfig:
    return R.TwoTowerConfig(
        name=ARCH, embed=R.EmbeddingSpec(USER_VOCABS + ITEM_VOCABS, 256),
        n_user_feats=len(USER_VOCABS), n_item_feats=len(ITEM_VOCABS),
        tower_mlp=(1024, 512, 256))


def reduced_config() -> R.TwoTowerConfig:
    return R.TwoTowerConfig(
        name=ARCH + "-smoke",
        embed=R.EmbeddingSpec((256, 64, 32, 16, 128, 64), 16),
        n_user_feats=4, n_item_feats=2, tower_mlp=(32, 16))


def shapes():
    return C.SHAPES


def _param_specs(params, mesh):
    def rule(path, leaf):
        if "table" in path:
            return P(("data", "model") if "pod" not in mesh.axis_names
                     else ("pod", "data", "model"), None)
        if leaf.ndim == 2 and leaf.shape[0] % mesh.shape["model"] == 0 \
                and leaf.shape[0] >= 256:
            return P("model", None)
        return P()
    return jax.tree_util.tree_map_with_path(
        lambda p, l: rule(jax.tree_util.keystr(p), l), params)


def _flops(cfg: R.TwoTowerConfig, batch: int) -> float:
    d = cfg.embed.dim
    user = C.mlp_params((cfg.n_user_feats * d,) + cfg.tower_mlp)
    item = C.mlp_params((cfg.n_item_feats * d,) + cfg.tower_mlp)
    return 6.0 * batch * (user + item)


def cell(shape_name, mesh) -> CellProgram:
    cfg = full_config()
    params = jax.eval_shape(lambda k: R.twotower_init(k, cfg),
                            jax.random.PRNGKey(0))
    pspecs = _param_specs(params, mesh)
    b = S.batch_axes(mesh)
    shp = C.SHAPES[shape_name]
    nu, ni = cfg.n_user_feats, cfg.n_item_feats

    if shape_name == "train_batch":
        bt = shp["batch"]

        def loss_of(p, uids, iids):
            return R.twotower_loss(p, cfg, uids, iids)

        return C.make_train_cell(
            ARCH, params, pspecs, mesh, loss_of,
            (C.sds((bt, nu), jnp.int32), C.sds((bt, ni), jnp.int32)),
            (P(b, None), P(b, None)), _flops(cfg, bt) * 3
            + 6.0 * bt * bt * cfg.tower_mlp[-1])

    if shape_name == "retrieval_cand":
        n = shp["n_candidates"]
        k = 100

        def fwd(p, uids, cand_vectors):
            return R.retrieval_scores(p, cfg, uids, cand_vectors, k=k)

        # candidate matrix sharded over all data axes (rows)
        return C.make_serve_cell(
            ARCH, shape_name, params, pspecs, fwd,
            (C.sds((1, nu), jnp.int32),
             C.sds((n, cfg.tower_mlp[-1]), jnp.float32)),
            (P(None, None), P(b, None)),
            _flops(cfg, 1) + 2.0 * n * cfg.tower_mlp[-1],
            out_specs=(P(), P()))

    bt = shp["batch"]

    def fwd(p, uids, iids):
        return R.twotower_scores(p, cfg, uids, iids)

    return C.make_serve_cell(
        ARCH, shape_name, params, pspecs, fwd,
        (C.sds((bt, nu), jnp.int32), C.sds((bt, ni), jnp.int32)),
        (P(b, None), P(b, None)),
        _flops(cfg, bt) + 2.0 * bt * bt * cfg.tower_mlp[-1],
        out_specs=P(b, None))


def smoke(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    cfg = reduced_config()
    p = R.twotower_init(key, cfg)
    uids = jax.random.randint(key, (16, cfg.n_user_feats), 0, 16)
    iids = jax.random.randint(key, (16, cfg.n_item_feats), 0, 16)
    loss = R.twotower_loss(p, cfg, uids, iids)
    cand = jax.random.normal(key, (512, cfg.tower_mlp[-1]))
    cand = cand / jnp.linalg.norm(cand, axis=-1, keepdims=True)
    scores, ids = R.retrieval_scores(p, cfg, uids[:1], cand, k=8)
    return {"loss": loss, "scores": scores, "ids": ids}
