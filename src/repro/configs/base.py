"""Arch registry: every assigned architecture is a module exposing

  FAMILY            "lm" | "gnn" | "recsys" | "metric"
  full_config()     exact published config (dry-run only — never allocated)
  reduced_config()  smoke-test config (CPU-runnable)
  shapes()          {shape_name: dims dict}
  cell(shape, mesh) CellProgram for the dry-run
  smoke(key)        runs one reduced forward/train step; returns outputs

CellProgram.inputs are ShapeDtypeStructs (no allocation); fn is the
jittable step; in_specs/out_specs are PartitionSpec pytrees.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Optional

ARCH_IDS = [
    "granite-moe-3b-a800m",
    "qwen3-moe-235b-a22b",
    "llama3.2-1b",
    "granite-3-2b",
    "nemotron-4-340b",
    "pna",
    "bst",
    "two-tower-retrieval",
    "dcn-v2",
    "dlrm-mlperf",
    "metric-search",          # the paper's own workload, as an arch
]

_MODULES = {
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "pna": "repro.configs.pna",
    "bst": "repro.configs.bst",
    "two-tower-retrieval": "repro.configs.two_tower_retrieval",
    "dcn-v2": "repro.configs.dcn_v2",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
    "metric-search": "repro.configs.metric_search",
}


@dataclasses.dataclass
class CellProgram:
    """One (arch x shape) dry-run cell."""
    arch: str
    shape: str
    kind: str                      # "train" | "serve"
    fn: Callable                   # step function (positional args)
    inputs: tuple                  # pytree of ShapeDtypeStruct, positional
    in_specs: tuple                # matching PartitionSpec pytrees
    out_specs: Any = None          # None => let GSPMD choose
    donate: tuple = ()
    model_flops_per_step: Optional[float] = None   # 6ND-style analytic


def get(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id])


def all_cells() -> list[tuple[str, str]]:
    out = []
    for a in ARCH_IDS:
        mod = get(a)
        for s in mod.shapes():
            out.append((a, s))
    return out
