"""qwen3-moe-235b-a22b  [moe] 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8  [hf:Qwen/Qwen3-30B-A3B]

128 experts divide the model axis (16): EXPERT-parallel, 8 experts per
shard (DESIGN.md §4)."""

from repro.configs import lm_common as C
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
ARCH = "qwen3-moe-235b-a22b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH, n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
        d_ff=1536, vocab=151936, act="silu", d_head=128,
        moe=MoEConfig(n_experts=128, top_k=8, d_model=4096, d_ff=1536,
                      group_size=32768),
        rope_theta=1000000.0)


def reduced_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        name=ARCH + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=32, vocab=512, act="silu", attn_block=32,
        moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32,
                      group_size=64),
        dtype=jnp.float32)


def shapes():
    return C.SHAPES


def cell(shape_name, mesh):
    return C.cell(ARCH, full_config(), shape_name, mesh)


def smoke(key=None):
    return C.smoke(reduced_config(), key)
