"""nemotron-4-340b  [dense] 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU, no gate  [arXiv:2402.16819]"""

from repro.configs import lm_common as C
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
ARCH = "nemotron-4-340b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH, n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
        d_ff=73728, vocab=256000, act="squared_relu", gated_mlp=False,
        rope_theta=10000.0)


def reduced_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        name=ARCH + "-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_ff=192, vocab=512, act="squared_relu",
        gated_mlp=False, attn_block=32, dtype=jnp.float32)


def shapes():
    return C.SHAPES


def cell(shape_name, mesh):
    return C.cell(ARCH, full_config(), shape_name, mesh)


def smoke(key=None):
    return C.smoke(reduced_config(), key)
