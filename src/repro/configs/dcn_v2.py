"""dcn-v2  [recsys] 13 dense / 26 sparse, embed_dim=16, 3 cross layers,
mlp=1024-1024-512  (Criteo Kaggle cardinalities)  [arXiv:2008.13535]"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import recsys_common as C
from repro.configs.base import CellProgram
from repro.models import recsys as R
from repro.sharding import specs as S

FAMILY = "recsys"
ARCH = "dcn-v2"

CRITEO_KAGGLE_VOCABS = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18,
    15, 286181, 105, 142572)


def full_config() -> R.DCNConfig:
    return R.DCNConfig(
        name=ARCH, n_dense=13,
        embed=R.EmbeddingSpec(CRITEO_KAGGLE_VOCABS, 16),
        n_cross=3, mlp=(1024, 1024, 512))


def reduced_config() -> R.DCNConfig:
    return R.DCNConfig(
        name=ARCH + "-smoke", n_dense=13,
        embed=R.EmbeddingSpec(tuple([32] * 26), 8),
        n_cross=2, mlp=(32, 16))


def shapes():
    return C.SHAPES


def _param_specs(params, mesh):
    def rule(path, leaf):
        if "table" in path:
            return P(("data", "model") if "pod" not in mesh.axis_names
                     else ("pod", "data", "model"), None)
        if leaf.ndim == 2 and leaf.shape[0] % mesh.shape["model"] == 0 \
                and leaf.shape[0] >= 256:
            return P("model", None)
        return P()
    return jax.tree_util.tree_map_with_path(
        lambda p, l: rule(jax.tree_util.keystr(p), l), params)


def _flops(cfg: R.DCNConfig, batch: int) -> float:
    d0 = cfg.n_dense + len(cfg.embed.vocab_sizes) * cfg.embed.dim
    cross = cfg.n_cross * d0 * d0 * 2
    mlps = C.mlp_params((d0,) + cfg.mlp) + cfg.mlp[-1]
    return 6.0 * batch * (cross + mlps)


def cell(shape_name, mesh) -> CellProgram:
    cfg = full_config()
    params = jax.eval_shape(lambda k: R.dcn_init(k, cfg),
                            jax.random.PRNGKey(0))
    pspecs = _param_specs(params, mesh)
    b = S.batch_axes(mesh)
    shp = C.SHAPES[shape_name]

    def fwd(p, dense, sp_ids):
        return R.dcn_forward(p, cfg, dense, sp_ids)

    if shape_name == "train_batch":
        bt = shp["batch"]

        def loss_of(p, dense, sp_ids, labels):
            return R.bce_loss(fwd(p, dense, sp_ids), labels)

        return C.make_train_cell(
            ARCH, params, pspecs, mesh, loss_of,
            (C.sds((bt, 13), jnp.float32), C.sds((bt, 26), jnp.int32),
             C.sds((bt,), jnp.float32)),
            (P(b, None), P(b, None), P(b)), _flops(cfg, bt) * 3)

    bt = shp["n_candidates"] if shape_name == "retrieval_cand" \
        else shp["batch"]
    return C.make_serve_cell(
        ARCH, shape_name, params, pspecs, fwd,
        (C.sds((bt, 13), jnp.float32), C.sds((bt, 26), jnp.int32)),
        (P(b, None), P(b, None)), _flops(cfg, bt), out_specs=P(b))


def smoke(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    cfg = reduced_config()
    p = R.dcn_init(key, cfg)
    dense = jax.random.normal(key, (16, 13))
    sp = jax.random.randint(key, (16, 26), 0, 32)
    labels = (jax.random.uniform(key, (16,)) < 0.3).astype(jnp.float32)
    logits = R.dcn_forward(p, cfg, dense, sp)
    loss = R.bce_loss(logits, labels)
    return {"logits": logits, "loss": loss}
