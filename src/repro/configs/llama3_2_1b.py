"""llama3.2-1b  [dense] 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256  [hf:meta-llama/Llama-3.2-1B]"""

from repro.configs import lm_common as C
from repro.models.transformer import TransformerConfig

FAMILY = "lm"
ARCH = "llama3.2-1b"


def full_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH, n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab=128256, act="silu", gated_mlp=True,
        rope_theta=500000.0)


def reduced_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        name=ARCH + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, act="silu", gated_mlp=True,
        attn_block=32, dtype=jnp.float32)


def shapes():
    return C.SHAPES


def cell(shape_name, mesh):
    return C.cell(ARCH, full_config(), shape_name, mesh)


def smoke(key=None):
    return C.smoke(reduced_config(), key)
