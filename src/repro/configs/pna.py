"""pna  [gnn] 4L d_hidden=75 aggregators=mean-max-min-std
scalers=id-amp-atten  [arXiv:2004.05718]

Shapes:
  full_graph_sm  n=2,708 e=10,556 d_feat=1,433      (cora-like, full batch)
  minibatch_lg   n=232,965 e=114,615,892 bs=1,024 fanout=15-10
                 (reddit-like; trains on SAMPLED padded blocks)
  ogb_products   n=2,449,029 e=61,859,140 d_feat=100 (full-batch-large)
  molecule       n=30 e=64 batch=128                (batched small graphs)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import CellProgram
from repro.models import gnn
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.sharding import specs as S

FAMILY = "gnn"
ARCH = "pna"
_OPT = AdamWConfig()

SHAPES = {
    "full_graph_sm": {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
                      "n_classes": 7, "kind": "train"},
    "minibatch_lg": {"n_nodes": 232965, "n_edges": 114615892,
                     "batch_nodes": 1024, "fanout": (15, 10),
                     "d_feat": 602, "n_classes": 41, "kind": "train",
                     # padded sampled-block sizes (pow2 of worst case)
                     "block_nodes": 262144, "block_edges": 262144},
    "ogb_products": {"n_nodes": 2449029, "n_edges": 61859140,
                     "d_feat": 100, "n_classes": 47, "kind": "train"},
    "molecule": {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16,
                 "n_classes": 2, "kind": "train"},
}


def full_config(shape_name: str = "full_graph_sm") -> gnn.PNAConfig:
    s = SHAPES[shape_name]
    return gnn.PNAConfig(name=ARCH, n_layers=4, d_hidden=75,
                         d_in=s["d_feat"], n_classes=s["n_classes"])


def reduced_config() -> gnn.PNAConfig:
    return gnn.PNAConfig(name=ARCH + "-smoke", n_layers=2, d_hidden=16,
                         d_in=8, n_classes=4)


def shapes():
    return SHAPES


def model_flops(cfg: gnn.PNAConfig, n: int, e: int) -> float:
    h = cfg.d_hidden
    fan_in = h * (1 + gnn.N_AGG * gnn.N_SCALE)
    per_layer = 6.0 * (e * 2 * h * h + n * fan_in * h)
    return (cfg.n_layers * per_layer + 6.0 * n * cfg.d_in * h
            + 6.0 * n * h * cfg.n_classes)


def _abstract(cfg):
    return jax.eval_shape(lambda k: gnn.init_params(k, cfg),
                          jax.random.PRNGKey(0))


def cell(shape_name, mesh) -> CellProgram:
    shp = SHAPES[shape_name]
    cfg = full_config(shape_name)
    params = _abstract(cfg)
    pspecs = S.pna_param_specs(params, mesh)
    opt = jax.eval_shape(adamw_init, params)
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    b = S.batch_axes(mesh)
    sds = jax.ShapeDtypeStruct

    if shape_name == "molecule":
        bt, nn, ee = shp["batch"], shp["n_nodes"], shp["n_edges"]
        total_n, total_e = bt * nn, bt * ee

        def train_step(params, opt_state, x, src, dst, graph_ids, labels):
            def loss(p):
                return gnn.loss_fn(p, cfg, x, src, dst, labels,
                                   graph_ids=graph_ids, n_graphs=bt)
            l, g = jax.value_and_grad(loss)(params)
            params, opt_state, _ = adamw_update(params, g, opt_state, _OPT)
            return params, opt_state, l

        inputs = (params, opt, sds((total_n, shp["d_feat"]), jnp.float32),
                  sds((total_e,), jnp.int32), sds((total_e,), jnp.int32),
                  sds((total_n,), jnp.int32), sds((bt,), jnp.int32))
        in_specs = (pspecs, ospecs, P(b, None), P(b), P(b), P(b), P())
        return CellProgram(
            ARCH, shape_name, "train", train_step, inputs, in_specs,
            out_specs=(pspecs, ospecs, P()), donate=(0, 1),
            model_flops_per_step=model_flops(cfg, total_n, total_e))

    if shape_name == "minibatch_lg":
        nn, ee = shp["block_nodes"], shp["block_edges"]

        def train_step(params, opt_state, x, src, dst, edge_mask, labels,
                       label_mask):
            def loss(p):
                return gnn.loss_fn(p, cfg, x, src, dst, labels,
                                   edge_mask=edge_mask,
                                   label_mask=label_mask)
            l, g = jax.value_and_grad(loss)(params)
            params, opt_state, _ = adamw_update(params, g, opt_state, _OPT)
            return params, opt_state, l

        inputs = (params, opt, sds((nn, shp["d_feat"]), jnp.float32),
                  sds((ee,), jnp.int32), sds((ee,), jnp.int32),
                  sds((ee,), jnp.bool_), sds((nn,), jnp.int32),
                  sds((nn,), jnp.float32))
        in_specs = (pspecs, ospecs, P(), P(b), P(b), P(b), P(), P())
        return CellProgram(
            ARCH, shape_name, "train", train_step, inputs, in_specs,
            out_specs=(pspecs, ospecs, P()), donate=(0, 1),
            model_flops_per_step=model_flops(cfg, nn, ee))

    # full-batch graphs: edges sharded over the batch axes and PADDED to
    # a 512-multiple (mask keeps semantics); features replicated at
    # `full_graph_sm` scale — products sharding revisited in §Perf.
    nn, ee = shp["n_nodes"], shp["n_edges"]
    ee_pad = ((ee + 511) // 512) * 512

    def train_step(params, opt_state, x, src, dst, edge_mask, labels):
        def loss(p):
            return gnn.loss_fn(p, cfg, x, src, dst, labels,
                               edge_mask=edge_mask)
        l, g = jax.value_and_grad(loss)(params)
        params, opt_state, _ = adamw_update(params, g, opt_state, _OPT)
        return params, opt_state, l

    inputs = (params, opt, sds((nn, shp["d_feat"]), jnp.float32),
              sds((ee_pad,), jnp.int32), sds((ee_pad,), jnp.int32),
              sds((ee_pad,), jnp.bool_), sds((nn,), jnp.int32))
    in_specs = (pspecs, ospecs, P(), P(b), P(b), P(b), P())
    return CellProgram(
        ARCH, shape_name, "train", train_step, inputs, in_specs,
        out_specs=(pspecs, ospecs, P()), donate=(0, 1),
        model_flops_per_step=model_flops(cfg, nn, ee))


def smoke(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    cfg = reduced_config()
    p = gnn.init_params(key, cfg)
    n, e = 60, 240
    x = jax.random.normal(key, (n, cfg.d_in))
    src = jax.random.randint(key, (e,), 0, n)
    dst = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    labels = jax.random.randint(key, (n,), 0, cfg.n_classes)
    opt = adamw_init(p)

    @jax.jit
    def step(p, o):
        l, g = jax.value_and_grad(
            lambda pp: gnn.loss_fn(pp, cfg, x, src, dst, labels))(p)
        p, o, _ = adamw_update(p, g, o, _OPT)
        return p, o, l

    p2, o2, loss = step(p, opt)
    logits = gnn.forward(p, cfg, x, src, dst)
    # sampled-block path (edge/label masks)
    em = jnp.ones((e,), bool).at[-10:].set(False)
    lm = jnp.zeros((n,)).at[:8].set(1.0)
    loss_mb = gnn.loss_fn(p, cfg, x, src, dst, labels, edge_mask=em,
                          label_mask=lm)
    # molecule path
    gi = jnp.repeat(jnp.arange(6), 10)
    glabels = jax.random.randint(key, (6,), 0, cfg.n_classes)
    loss_mol = gnn.loss_fn(p, cfg, x, src, dst, glabels, graph_ids=gi,
                           n_graphs=6)
    return {"loss": loss, "logits": logits, "loss_mb": loss_mb,
            "loss_mol": loss_mol}
