"""Shared cell/smoke machinery for the 4 recsys archs.

Shapes (assignment):
  train_batch     batch=65,536      -> train_step (BCE / sampled softmax)
  serve_p99       batch=512         -> forward
  serve_bulk      batch=262,144     -> forward
  retrieval_cand  batch=1, n_candidates=1,000,000
                  -> two-tower: dot-scoring + top-k (the paper's workload)
                  -> CTR models: batched forward over all candidates
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import CellProgram
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.sharding import specs as S

SHAPES = {
    "train_batch": {"batch": 65536, "kind": "train"},
    "serve_p99": {"batch": 512, "kind": "serve"},
    "serve_bulk": {"batch": 262144, "kind": "serve"},
    "retrieval_cand": {"batch": 1, "n_candidates": 1000000,
                       "kind": "serve"},
}

OPT = AdamWConfig()

sds = jax.ShapeDtypeStruct


def shapes():
    return SHAPES


def make_train_cell(arch, params, pspecs, mesh, loss_of, batch_inputs,
                    batch_specs, flops) -> CellProgram:
    opt = jax.eval_shape(adamw_init, params)
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}

    def train_step(params, opt_state, *batch):
        l, g = jax.value_and_grad(lambda p: loss_of(p, *batch))(params)
        params, opt_state, _ = adamw_update(params, g, opt_state, OPT)
        return params, opt_state, l

    return CellProgram(
        arch, "train_batch", "train", train_step,
        (params, opt) + tuple(batch_inputs),
        (pspecs, ospecs) + tuple(batch_specs),
        out_specs=(pspecs, ospecs, P()), donate=(0, 1),
        model_flops_per_step=flops)


def make_serve_cell(arch, shape_name, params, pspecs, fwd, batch_inputs,
                    batch_specs, flops, out_specs=None) -> CellProgram:
    return CellProgram(
        arch, shape_name, "serve", fwd,
        (params,) + tuple(batch_inputs), (pspecs,) + tuple(batch_specs),
        out_specs=out_specs, model_flops_per_step=flops)


def mlp_params(sizes) -> int:
    return sum(sizes[i] * sizes[i + 1] for i in range(len(sizes) - 1))
