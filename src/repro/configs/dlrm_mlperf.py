"""dlrm-mlperf  [recsys] 13 dense / 26 sparse, embed_dim=128,
bot_mlp=13-512-256-128, top_mlp=1024-1024-512-256-1, dot interaction
(Criteo 1TB / MLPerf)  [arXiv:1906.00091]

Embedding tables use the Criteo Terabyte cardinalities (~188M rows x 128
= ~96 GB f32) sharded over EVERY chip (rows over ("data","model")) — the
canonical DLRM model-parallel layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import recsys_common as C
from repro.configs.base import CellProgram
from repro.models import recsys as R
from repro.sharding import specs as S

FAMILY = "recsys"
ARCH = "dlrm-mlperf"

CRITEO_TB_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36)


def full_config() -> R.DLRMConfig:
    return R.DLRMConfig(
        name=ARCH, n_dense=13,
        embed=R.EmbeddingSpec(CRITEO_TB_VOCABS, 128),
        bot_mlp=(13, 512, 256, 128),
        top_mlp=(1024, 1024, 512, 256, 1))


def reduced_config() -> R.DLRMConfig:
    return R.DLRMConfig(
        name=ARCH + "-smoke", n_dense=13,
        embed=R.EmbeddingSpec(tuple([64] * 26), 16),
        bot_mlp=(13, 32, 16), top_mlp=(64, 32, 1))


def shapes():
    return C.SHAPES


def _param_specs(params, mesh, *, serve: bool = False):
    """Training: table ROWS over every chip (memory: 96 GB of table + two
    Adam moments).  Serving: table COLUMNS over "model" — each shard owns
    all rows x dim/16, so the hot-path lookup is collective-FREE (§Perf:
    the row-sharded gather cost 13.3 GB of all-reduce per step); the
    (tiny) MLPs replicate and run fully batch-parallel."""
    baxes = S.batch_axes(mesh)
    table_rows = (baxes + ("model",)) if isinstance(baxes, tuple) \
        else ("data", "model")

    def rule(path, leaf):
        if "table" in path:
            return P(None, "model") if serve else P(table_rows, None)
        if serve:
            return P()
        if leaf.ndim == 2 and leaf.shape[0] % mesh.shape["model"] == 0 \
                and leaf.shape[0] >= 256:
            return P("model", None)
        return P()
    return jax.tree_util.tree_map_with_path(
        lambda p, l: rule(jax.tree_util.keystr(p), l), params)


def _flops(cfg: R.DLRMConfig, batch: int) -> float:
    n_f = cfg.n_sparse + 1
    inter = n_f * n_f * cfg.embed.dim * 2
    mlps = C.mlp_params(cfg.bot_mlp) \
        + C.mlp_params((cfg.embed.dim + n_f * (n_f - 1) // 2,)
                       + cfg.top_mlp[1:])
    return 6.0 * batch * (mlps + inter)


def cell(shape_name, mesh) -> CellProgram:
    cfg = full_config()
    params = jax.eval_shape(lambda k: R.dlrm_init(k, cfg),
                            jax.random.PRNGKey(0))
    pspecs = _param_specs(params, mesh,
                          serve=C.SHAPES[shape_name]["kind"] == "serve")
    b = S.batch_axes(mesh)
    shp = C.SHAPES[shape_name]

    if shape_name == "train_batch":
        bt = shp["batch"]

        def loss_of(p, dense, sp_ids, labels):
            return R.bce_loss(R.dlrm_forward(p, cfg, dense, sp_ids), labels)

        return C.make_train_cell(
            ARCH, params, pspecs, mesh, loss_of,
            (C.sds((bt, 13), jnp.float32), C.sds((bt, 26), jnp.int32),
             C.sds((bt,), jnp.float32)),
            (P(b, None), P(b, None), P(b)), _flops(cfg, bt) * 3)

    # serve cells: candidates sharded over EVERY mesh axis (§Perf iter 2:
    # each chip scores batch/256 rows; the only collective left is the
    # (rows_local, 26, 128) dim-completion psum over "model")
    bm = (b + ("model",)) if isinstance(b, tuple) else (b, "model")
    bt = shp["n_candidates"] if shape_name == "retrieval_cand" \
        else shp["batch"]
    bt = ((bt + 511) // 512) * 512    # pad serve batch to shard evenly

    def fwd(p, dense, sp_ids):
        return R.dlrm_forward(p, cfg, dense, sp_ids)

    return C.make_serve_cell(
        ARCH, shape_name, params, pspecs, fwd,
        (C.sds((bt, 13), jnp.float32), C.sds((bt, 26), jnp.int32)),
        (P(bm, None), P(bm, None)), _flops(cfg, bt), out_specs=P(bm))


def smoke(key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    cfg = reduced_config()
    p = R.dlrm_init(key, cfg)
    dense = jax.random.normal(key, (16, 13))
    sp = jax.random.randint(key, (16, 26), 0, 64)
    labels = (jax.random.uniform(key, (16,)) < 0.3).astype(jnp.float32)
    logits = R.dlrm_forward(p, cfg, dense, sp)
    loss = R.bce_loss(logits, labels)
    g = jax.grad(lambda pp: R.bce_loss(
        R.dlrm_forward(pp, cfg, dense, sp), labels))(p)
    return {"logits": logits, "loss": loss, "grads": g}
