"""Exact brute-force scan — correctness oracle and the dense-retrieval
backend (recsys ``retrieval_cand`` path).

All distance evaluation goes through ``repro.core.blockdist`` — the
kernel layer shared with traversal and serving — which dispatches to the
Pallas pairwise kernels when REPRO_GATHER_IMPL=pallas and to pure jnp
otherwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockdist import pairwise_distance

Array = jnp.ndarray


def _blocked(data: Array, n: int, block: int):
    """(nblk, block, d) zero-padded view + (nblk, block) validity mask."""
    nblk = (n + block - 1) // block
    pad = nblk * block - n
    dblk = jnp.pad(data, ((0, pad), (0, 0))).reshape(nblk, block, -1)
    valid = (jnp.arange(nblk * block) < n).reshape(nblk, block)
    return dblk, valid


@functools.partial(jax.jit, static_argnames=("metric_name", "block"))
def _range_counts(data: Array, queries: Array, t: Array, *,
                  metric_name: str, block: int) -> tuple[Array, Array]:
    """(counts (Q,), n_dist (Q,)) of exact range search via blocked scan."""
    nq = queries.shape[0]
    n = data.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (nq,))
    dblk, valid = _blocked(data, n, block)

    def scan_body(cnt, xs):
        blk, vmask = xs
        d = pairwise_distance(metric_name, queries, blk)   # (Q, block)
        hits = (d <= t[:, None]) & vmask[None, :]
        return cnt + jnp.sum(hits, axis=1, dtype=jnp.int32), None

    cnt, _ = jax.lax.scan(scan_body, jnp.zeros((nq,), jnp.int32),
                          (dblk, valid))
    return cnt, jnp.full((nq,), n, jnp.int32)


@functools.partial(jax.jit, static_argnames=("metric_name", "block"))
def _range_hits(data: Array, queries: Array, t: Array, *,
                metric_name: str, block: int) -> Array:
    """(Q, nblk*block) bool hit mask via the jitted blocked scan — one
    device program regardless of n (padded columns are False)."""
    nq = queries.shape[0]
    n = data.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (nq,))
    dblk, valid = _blocked(data, n, block)

    def scan_body(_, xs):
        blk, vmask = xs
        d = pairwise_distance(metric_name, queries, blk)   # (Q, block)
        return None, (d <= t[:, None]) & vmask[None, :]

    _, hits = jax.lax.scan(scan_body, None, (dblk, valid))  # (nblk, Q, blk)
    return jnp.moveaxis(hits, 0, 1).reshape(nq, -1)


def range_search(data, queries, t, *, metric_name: str,
                 block: int = 8192) -> tuple[np.ndarray, list[set[int]]]:
    """Exact range search. Returns (counts, per-query id sets).

    The scan itself is the jitted blocked kernel (scales with n); only
    the id-set materialisation is host-side, from the (Q, n) boolean.
    For count-only workloads at large n use ``range_counts``.
    """
    data = jnp.asarray(data, jnp.float32)
    queries = jnp.asarray(queries, jnp.float32)
    hits = np.asarray(_range_hits(data, queries, t,
                                  metric_name=metric_name,
                                  block=block))[:, :data.shape[0]]
    sets = [set(np.nonzero(hits[i])[0].tolist())
            for i in range(hits.shape[0])]
    return hits.sum(axis=1), sets


def range_counts(data, queries, t, *, metric_name: str,
                 block: int = 8192) -> np.ndarray:
    cnt, _ = _range_counts(jnp.asarray(data, jnp.float32),
                           jnp.asarray(queries, jnp.float32), t,
                           metric_name=metric_name, block=block)
    return np.asarray(cnt)


@functools.partial(jax.jit, static_argnames=("metric_name", "k"))
def knn(data: Array, queries: Array, *, metric_name: str,
        k: int) -> tuple[Array, Array]:
    """Exact k-NN: (distances (Q,k), ids (Q,k)). Single pairwise block —
    used by the retrieval serving path where n fits (10^6 x d).

    Ties are broken toward lower ids (``lax.top_k``'s rule) — the same
    (distance, id) order the tree k-NN engines and ``forest_knn`` use.
    When k > n the trailing slots hold (+inf, -1), matching the tree
    engines' padding.
    """
    d = pairwise_distance(metric_name, queries, data)
    kk = min(k, d.shape[1])
    neg, idx = jax.lax.top_k(-d, kk)
    if kk < k:
        pad = ((0, 0), (0, k - kk))
        neg = jnp.pad(neg, pad, constant_values=-jnp.inf)
        idx = jnp.pad(idx, pad, constant_values=-1)
    return -neg, idx
