"""Exact brute-force scan — correctness oracle and the dense-retrieval
backend (recsys ``retrieval_cand`` path).

Dispatches to the Pallas pairwise kernels for MXU-friendly metrics when
``use_kernels=True`` (interpret mode on CPU); otherwise pure jnp blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as metrics_lib

Array = jnp.ndarray


@functools.partial(jax.jit, static_argnames=("metric_name", "block"))
def _range_counts(data: Array, queries: Array, t: Array, *,
                  metric_name: str, block: int) -> tuple[Array, Array]:
    """(counts (Q,), n_dist (Q,)) of exact range search via blocked scan."""
    metric = metrics_lib.get(metric_name)
    nq = queries.shape[0]
    n = data.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (nq,))
    nblk = (n + block - 1) // block
    pad = nblk * block - n
    dpad = jnp.pad(data, ((0, pad), (0, 0)))
    dblk = dpad.reshape(nblk, block, -1)
    valid = (jnp.arange(nblk * block) < n).reshape(nblk, block)

    def scan_body(cnt, xs):
        blk, vmask = xs
        d = metric.pairwise(queries, blk)            # (Q, block)
        hits = (d <= t[:, None]) & vmask[None, :]
        return cnt + jnp.sum(hits, axis=1, dtype=jnp.int32), None

    cnt, _ = jax.lax.scan(scan_body, jnp.zeros((nq,), jnp.int32),
                          (dblk, valid))
    return cnt, jnp.full((nq,), n, jnp.int32)


def range_search(data, queries, t, *, metric_name: str,
                 block: int = 8192) -> tuple[np.ndarray, list[set[int]]]:
    """Exact range search. Returns (counts, per-query id sets).

    The id sets are produced host-side from a (Q, n) boolean — intended
    for test-sized n. For large n use ``range_counts``.
    """
    metric = metrics_lib.get(metric_name)
    data = jnp.asarray(data, jnp.float32)
    queries = jnp.asarray(queries, jnp.float32)
    nq = queries.shape[0]
    t_arr = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (nq,))
    hits_np = []
    n = data.shape[0]
    for s in range(0, n, block):
        d = metric.pairwise(queries, data[s:s + block])
        hits_np.append(np.asarray(d <= t_arr[:, None]))
    hits = np.concatenate(hits_np, axis=1)
    sets = [set(np.nonzero(hits[i])[0].tolist()) for i in range(nq)]
    return hits.sum(axis=1), sets


def range_counts(data, queries, t, *, metric_name: str,
                 block: int = 8192) -> np.ndarray:
    cnt, _ = _range_counts(jnp.asarray(data, jnp.float32),
                           jnp.asarray(queries, jnp.float32), t,
                           metric_name=metric_name, block=block)
    return np.asarray(cnt)


@functools.partial(jax.jit, static_argnames=("metric_name", "k"))
def knn(data: Array, queries: Array, *, metric_name: str,
        k: int) -> tuple[Array, Array]:
    """Exact k-NN: (distances (Q,k), ids (Q,k)). Single pairwise block —
    used by the retrieval serving path where n fits (10^6 x d)."""
    metric = metrics_lib.get(metric_name)
    d = metric.pairwise(queries, data)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx
