"""Metric registry for Hilbert-Exclusion search.

Every metric carries a ``four_point_property`` capability flag: True iff the
space is isometrically 4-embeddable in l2^3 (equivalently, Hilbert-space
embeddable for the metrics here — paper §5).  Hilbert Exclusion is only
valid when the flag is True; the search layer enforces this.

All distance functions are pure jnp, batched over leading axes:

  ``pairwise(X, Y)``    -> (n, m) distances between rows of X (n,d), Y (m,d)
  ``one_to_many(q, X)`` -> (n,)   distances from q (d,) to rows of X (n,d)

Probability-simplex metrics (jsd / triangular) assume inputs are
nonnegative and row-normalised to sum 1 (paper §6.1 note 6: euc/tri data
are normalised in the experiments; we expose ``normalise_for``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray

_EPS = 1e-12


# ---------------------------------------------------------------------------
# distance kernels (pure jnp; Pallas-accelerated versions live in
# repro.kernels and are dispatched by repro.core.bruteforce)
# ---------------------------------------------------------------------------

def _sq_l2_pairwise(x: Array, y: Array) -> Array:
    """Squared Euclidean via the MXU-friendly expansion |x|^2+|y|^2-2xy."""
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def euclidean_pairwise(x: Array, y: Array) -> Array:
    return jnp.sqrt(_sq_l2_pairwise(x, y))


def sqeuclidean_pairwise(x: Array, y: Array) -> Array:
    return _sq_l2_pairwise(x, y)


def _normalise_rows(x: Array) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), _EPS)


def cosine_pairwise(x: Array, y: Array) -> Array:
    """d_cos(v,w) = sqrt(1 - cos(v,w))  (paper §5.5, Hilbert-embeddable form).

    Equivalent to (1/sqrt(2))·||v/|v| - w/|w|||_2, hence 4-embeddable.
    """
    xn = _normalise_rows(x)
    yn = _normalise_rows(y)
    sim = jnp.clip(xn @ yn.T, -1.0, 1.0)
    return jnp.sqrt(jnp.maximum(1.0 - sim, 0.0))


def angular_pairwise(x: Array, y: Array) -> Array:
    """1 - arccos(cos)/(2*pi): rank-equivalent 'Cosine Distance' that the paper
    notes is a proper metric but NOT Hilbert-embeddable (§5.5). Kept as a
    negative control for the four-point flag.

    NOTE: we use arccos(cos)/pi (bounded [0,1] and a proper metric on the
    sphere); the paper's 1 - acos/2pi is not a metric as written (d(x,x)=1)
    and is presumed a typo. Rank order is unaffected.
    """
    xn = _normalise_rows(x)
    yn = _normalise_rows(y)
    sim = jnp.clip(xn @ yn.T, -1.0, 1.0)
    return jnp.arccos(sim) / jnp.pi


def _h(x: Array) -> Array:
    """h(x) = -x log2 x, with h(0) = 0."""
    safe = jnp.where(x > _EPS, x, 1.0)
    return jnp.where(x > _EPS, -safe * jnp.log2(safe), 0.0)


def jsd_divergence_pairwise(x: Array, y: Array) -> Array:
    """JSD(v,w) = 1 - 1/2 sum_i (h(v_i)+h(w_i)-h(v_i+w_i))   (paper §5.3).

    Bounded [0,1]. x:(n,d), y:(m,d) -> (n,m). The cross term h(v+w) cannot
    be factored into a matmul; it is the VPU-bound O(n·m·d) loop that the
    Pallas kernel tiles.
    """
    hx = jnp.sum(_h(x), axis=-1)[:, None]          # (n,1)
    hy = jnp.sum(_h(y), axis=-1)[None, :]          # (1,m)
    xpy = x[:, None, :] + y[None, :, :]            # (n,m,d)
    hxy = jnp.sum(_h(xpy), axis=-1)                # (n,m)
    return 1.0 - 0.5 * (hx + hy - hxy)


def jsd_pairwise(x: Array, y: Array) -> Array:
    """Jensen-Shannon *distance* = sqrt(JSD) — the proper, Hilbert-embeddable
    metric (Topsoe / Endres-Schindelin)."""
    return jnp.sqrt(jnp.maximum(jsd_divergence_pairwise(x, y), 0.0))


def triangular_pairwise(x: Array, y: Array) -> Array:
    """D_tri(v,w) = sqrt( sum_i (v_i-w_i)^2 / (v_i+w_i) )   (paper §5.4)."""
    diff2 = (x[:, None, :] - y[None, :, :]) ** 2   # (n,m,d)
    denom = x[:, None, :] + y[None, :, :]
    terms = jnp.where(denom > _EPS, diff2 / jnp.maximum(denom, _EPS), 0.0)
    return jnp.sqrt(jnp.maximum(jnp.sum(terms, axis=-1), 0.0))


def manhattan_pairwise(x: Array, y: Array) -> Array:
    """L1 — a proper metric WITHOUT the four-point property (paper §5.7)."""
    return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def chebyshev_pairwise(x: Array, y: Array) -> Array:
    """L-inf — proper metric, not Hilbert embeddable (paper §5.7)."""
    return jnp.max(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def sqrt_manhattan_pairwise(x: Array, y: Array) -> Array:
    """sqrt(L1): Blumenthal — (X, d^alpha) with alpha<=1/2 is 4-embeddable
    (paper §5.7), so THIS form may use Hilbert exclusion (at the price of
    much higher intrinsic dimensionality)."""
    return jnp.sqrt(manhattan_pairwise(x, y))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Metric:
    """A metric space descriptor.

    four_point_property: True iff isometrically 4-embeddable in l2^3
        (=> Hilbert Exclusion is sound; Theorem 2).
    simplex: inputs must be probability vectors (row-normalised, >=0).
    mxu_friendly: the pairwise form reduces to a matmul (+rank-1 terms).
    """
    name: str
    pairwise: Callable[[Array, Array], Array]
    four_point_property: bool
    simplex: bool = False
    mxu_friendly: bool = False

    def one_to_many(self, q: Array, x: Array) -> Array:
        return self.pairwise(q[None, :], x)[0]

    def __call__(self, a: Array, b: Array) -> Array:
        return self.pairwise(a[None, :], b[None, :])[0, 0]


_REGISTRY: dict[str, Metric] = {}


def register(metric: Metric) -> Metric:
    if metric.name in _REGISTRY:
        raise ValueError(f"duplicate metric {metric.name!r}")
    _REGISTRY[metric.name] = metric
    return metric


euclidean = register(Metric("euclidean", euclidean_pairwise,
                            four_point_property=True, mxu_friendly=True))
sqeuclidean = register(Metric("sqeuclidean", sqeuclidean_pairwise,
                              # d^2 is NOT a metric (no triangle ineq.);
                              # registered for kernel reuse only.
                              four_point_property=False, mxu_friendly=True))
cosine = register(Metric("cosine", cosine_pairwise,
                         four_point_property=True, mxu_friendly=True))
angular = register(Metric("angular", angular_pairwise,
                          four_point_property=False, mxu_friendly=True))
jsd = register(Metric("jsd", jsd_pairwise,
                      four_point_property=True, simplex=True))
triangular = register(Metric("triangular", triangular_pairwise,
                             four_point_property=True, simplex=True))
manhattan = register(Metric("manhattan", manhattan_pairwise,
                            four_point_property=False))
chebyshev = register(Metric("chebyshev", chebyshev_pairwise,
                            four_point_property=False))
sqrt_manhattan = register(Metric("sqrt_manhattan", sqrt_manhattan_pairwise,
                                 four_point_property=True))


def get(name: str) -> Metric:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown metric {name!r}; known: {sorted(_REGISTRY)}") from None


def names() -> list[str]:
    return sorted(_REGISTRY)


def normalise_for(metric: Metric, x: Array) -> Array:
    """Prepare raw nonnegative vectors for a metric (paper §6.1: euc/tri/jsd
    experiments normalise rows to sum 1 for simplex metrics)."""
    if metric.simplex:
        s = jnp.maximum(jnp.sum(x, axis=-1, keepdims=True), _EPS)
        return x / s
    return x
