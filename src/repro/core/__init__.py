"""repro.core — the paper's contribution: Hilbert Exclusion metric search.

Public API:
  metrics        metric registry with four-point capability flags
  exclusion      hyperbolic / hilbert / ball exclusion predicates
  embeddings     Lemma-5 four-point verifiers
  idim           intrinsic dimensionality + threshold calibration
  tree           GHT / MHT / DiSAT builders + jittable batched search
  bruteforce     exact-scan oracle / dense retrieval backend
  distributed    shard_map forest search
"""

from repro.core import metrics, exclusion, embeddings, idim  # noqa: F401
from repro.core import bruteforce, blockdist  # noqa: F401
from repro.core.tree import (  # noqa: F401
    build_ght, build_mht, build_disat,
    search_binary_tree, search_sat, SearchStats,
    BinaryHyperplaneTree, SATree,
)
