"""Distributed metric search: a forest of shard-local trees under
``shard_map`` (DESIGN.md §2.5).

Scale-out model (matches production vector-search systems):
  * the dataset is sharded over the ``data`` mesh axis; each shard builds
    an independent local index (no cross-shard tree edges => no pointer
    chasing over ICI);
  * queries are replicated to every shard;
  * each shard runs the SAME jittable traversal as the single-device
    engine; per-shard fixed-size result buffers are merged with an
    all_gather; distance counts are psum-reduced (the global cost).

Shard-local ids are offset into the global id space host-side at build.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.tree.build import build_ght, build_mht
from repro.core.tree.flat import BinaryHyperplaneTree
from repro.core.tree.search import _ID_SENT, _knn_binary, _search_binary


@dataclasses.dataclass
class ShardedForest:
    """Per-shard trees stacked on a leading shard axis, device-sharded.

    ``id_offset``: (n_shards, 1) global id offset per shard; -1 marks a
    FALLBACK shard (the data didn't divide evenly and this shard holds a
    duplicate of point 0 purely to keep shapes uniform) whose results
    and distance counts must be masked out of every reduction.
    """
    trees: BinaryHyperplaneTree      # every leaf has leading dim = n_shards
    mesh: Mesh
    axis: str
    id_offset: Any                   # (n_shards, 1) offset, -1 = fallback
    n_total: int


def _pad_tree(tree: BinaryHyperplaneTree, n_nodes: int, n_bucket: int
              ) -> BinaryHyperplaneTree:
    """Pad node/bucket arrays so every shard has identical shapes."""
    def pad1(a, target, fill):
        a = np.asarray(a)
        out = np.full((target,) + a.shape[1:], fill, a.dtype)
        out[:a.shape[0]] = a
        return out
    return BinaryHyperplaneTree(
        data=tree.data, perm=pad1(tree.perm, n_bucket, 0),
        p1=pad1(tree.p1, n_nodes, -1), p2=pad1(tree.p2, n_nodes, -1),
        d12=pad1(tree.d12, n_nodes, 0.0),
        p1_inherited=pad1(tree.p1_inherited, n_nodes, 0),
        cover_r1=pad1(tree.cover_r1, n_nodes, 0.0),
        cover_r2=pad1(tree.cover_r2, n_nodes, 0.0),
        left=pad1(tree.left, n_nodes, -1),
        right=pad1(tree.right, n_nodes, -1),
        leaf_start=pad1(tree.leaf_start, n_nodes, 0),
        leaf_count=pad1(tree.leaf_count, n_nodes, 0),
        norm_sq=tree.norm_sq,
    )


def build_forest(data: np.ndarray, metric_name: str, mesh: Mesh,
                 axis: str = "data", *, kind: str = "mht",
                 leaf_size: int = 32, seed: int = 0) -> ShardedForest:
    """Shard ``data`` over ``axis`` of ``mesh`` and build one local tree
    per shard (host-side), then device-put the stacked forest sharded on
    its leading axis."""
    n_shards = mesh.shape[axis]
    n = data.shape[0]
    per = (n + n_shards - 1) // n_shards
    builder = {"ght": build_ght, "mht": build_mht}[kind]
    trees, offsets = [], []
    for s in range(n_shards):
        lo, hi = s * per, min((s + 1) * per, n)
        shard_pts = data[lo:hi]
        if shard_pts.shape[0] == 0:
            # n doesn't divide: build a shape-compatible dummy tree over a
            # duplicate of point 0 and mark the shard with offset -1 so
            # _run masks its (duplicate) results and distance counts out
            # — otherwise global id 0 is returned by two shards and
            # res_cnt / n_dist are double-counted.
            shard_pts = data[:1]
            lo = -1
        trees.append(builder(shard_pts, metric_name,
                             leaf_size=leaf_size, seed=seed + s))
        offsets.append(lo)
    n_nodes = max(t.p1.shape[0] for t in trees)
    n_bucket = max(t.perm.shape[0] for t in trees)
    n_pts = max(t.data.shape[0] for t in trees)
    padded = []
    for t in trees:
        t = _pad_tree(t, n_nodes, n_bucket)
        dpad = np.zeros((n_pts, t.data.shape[1]), np.float32)
        dpad[:t.data.shape[0]] = t.data
        npad = np.zeros((n_pts,), np.float32)
        npad[:t.norm_sq.shape[0]] = t.norm_sq
        t = dataclasses.replace(t, data=dpad, norm_sq=npad)
        padded.append(t)
    stacked = jax.tree_util.tree_map(
        lambda *xs: np.stack(xs, axis=0), *padded)
    sharding = NamedSharding(mesh, P(axis))
    stacked = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, sharding), stacked)
    return ShardedForest(trees=stacked, mesh=mesh, axis=axis,
                         id_offset=jax.device_put(
                             jnp.asarray(offsets, jnp.int32)[:, None],
                             sharding),
                         n_total=n)


def _refuse_overflows(ctx: str, n_sovf, n_iovf, *, n_rovf=0, stack_cap,
                      frontier, r_cap=None, max_iter=None) -> None:
    """The forest twin of ``search.check_complete``: refuse silently
    truncated results, from psum'd per-(query, shard) overflow counts."""
    if int(n_sovf):
        raise RuntimeError(
            f"{ctx}: traversal stack overflow on {int(n_sovf)} "
            f"(query, shard) lanes — raise stack_cap (={stack_cap}) or "
            f"lower frontier (={frontier})")
    if int(n_rovf):
        raise RuntimeError(
            f"{ctx}: result buffer overflow on {int(n_rovf)} "
            f"(query, shard) lanes — raise r_cap (={r_cap})")
    if int(n_iovf):
        raise RuntimeError(
            f"{ctx}: iteration budget exhausted on {int(n_iovf)} "
            f"(query, shard) lanes — results would be silently "
            f"truncated; raise max_iter (={max_iter})")


def forest_search(forest: ShardedForest, queries, t, *, metric_name: str,
                  mechanism: str = "hilbert", r_cap: int = 64,
                  stack_cap: int = 256, frontier: int = 8,
                  max_iter: int | None = None):
    """Replicated-query forest search.

    Returns (res_ids (Q, n_shards*r_cap) global ids, res_cnt (Q,),
    n_dist (Q,) summed over non-fallback shards).
    """
    mesh, axis = forest.mesh, forest.axis
    leaf_cap = int(np.max(np.asarray(forest.trees.leaf_count)))
    queries = jnp.asarray(queries, jnp.float32)
    tq = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (queries.shape[0],))

    tree_specs = jax.tree_util.tree_map(lambda _: P(axis), forest.trees)

    @partial(shard_map, mesh=mesh,
             in_specs=(tree_specs, P(axis), P(), P()),
             out_specs=(P(None, axis), P(), P(), P(), P(), P()),
             check_rep=False)
    def _run(tree, id_off, q, tt):
        # leading shard axis has local length 1 inside the map
        tree = jax.tree_util.tree_map(lambda x: x[0], tree)
        stats = _search_binary(
            tree, q, tt, metric_name=metric_name, mechanism=mechanism,
            r_cap=r_cap, stack_cap=stack_cap, leaf_cap=max(leaf_cap, 1),
            frontier=frontier, use_cover_radius=True, max_iter=max_iter)
        # fallback shards (offset -1) hold a duplicate of point 0: their
        # results AND distance counts are masked out of every reduction
        fb = id_off[0, 0] < 0
        valid = (stats.res_ids >= 0) & ~fb
        gids = jnp.where(valid,
                         stats.res_ids + jnp.maximum(id_off[0, 0], 0), -1)
        zero = jnp.zeros_like(stats.res_cnt)
        cnt = jax.lax.psum(jnp.where(fb, zero, stats.res_cnt), axis)
        nd = jax.lax.psum(jnp.where(fb, zero, stats.n_dist), axis)
        n_sovf = jax.lax.psum(
            jnp.sum(stats.stack_overflow.astype(jnp.int32)), axis)
        n_rovf = jax.lax.psum(
            jnp.sum(stats.overflow.astype(jnp.int32)), axis)
        n_iovf = jax.lax.psum(
            jnp.sum(stats.iter_overflow.astype(jnp.int32)), axis)
        return gids, cnt, nd, n_sovf, n_rovf, n_iovf

    gids, cnt, nd, n_sovf, n_rovf, n_iovf = _run(
        forest.trees, forest.id_offset, queries, tq)
    # exactness contract: a dropped stack entry, result slot or iteration
    # budget means the returned sets are silently truncated — refuse
    _refuse_overflows("forest_search", n_sovf, n_iovf, n_rovf=n_rovf,
                      stack_cap=stack_cap, frontier=frontier, r_cap=r_cap,
                      max_iter=max_iter)
    return gids, cnt, nd


def forest_knn(forest: ShardedForest, queries, k: int, *, metric_name: str,
               mechanism: str = "hilbert", stack_cap: int = 256,
               frontier: int = 8, max_iter: int | None = None):
    """Exact distributed k-NN: per-shard local k-NN under ``shard_map``
    (each shard runs the shrinking-radius engine against its own local
    k-th best), all-gather of the (Q, n_shards*k) candidates, then a
    global (distance, id) top-k merge.

    Any global k-NN member is necessarily in its own shard's local top-k,
    so the merge of local top-ks is exact; ties resolve to the smallest
    global id, identical to ``bruteforce.knn``.  Returns (dists (Q, k),
    ids (Q, k) global ids with -1 padding when k > n, n_dist (Q,) summed
    over non-fallback shards).
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    mesh, axis = forest.mesh, forest.axis
    leaf_cap = int(np.max(np.asarray(forest.trees.leaf_count)))
    queries = jnp.asarray(queries, jnp.float32)

    tree_specs = jax.tree_util.tree_map(lambda _: P(axis), forest.trees)

    @partial(shard_map, mesh=mesh,
             in_specs=(tree_specs, P(axis), P()),
             out_specs=(P(None, axis), P(None, axis), P(), P(), P()),
             check_rep=False)
    def _run(tree, id_off, q):
        tree = jax.tree_util.tree_map(lambda x: x[0], tree)
        st = _knn_binary(
            tree, q, metric_name=metric_name, mechanism=mechanism, k=k,
            stack_cap=stack_cap, leaf_cap=max(leaf_cap, 1),
            frontier=frontier, use_cover_radius=True, max_iter=max_iter)
        fb = id_off[0, 0] < 0
        ok = (st.ids >= 0) & ~fb
        gids = jnp.where(ok, st.ids + jnp.maximum(id_off[0, 0], 0),
                         _ID_SENT)
        gd = jnp.where(ok, st.dists, jnp.inf)
        nd = jax.lax.psum(
            jnp.where(fb, jnp.zeros_like(st.n_dist), st.n_dist), axis)
        n_sovf = jax.lax.psum(
            jnp.sum(st.stack_overflow.astype(jnp.int32)), axis)
        n_iovf = jax.lax.psum(
            jnp.sum(st.iter_overflow.astype(jnp.int32)), axis)
        return gd, gids, nd, n_sovf, n_iovf

    gd, gids, nd, n_sovf, n_iovf = _run(forest.trees, forest.id_offset,
                                        queries)
    _refuse_overflows("forest_knn", n_sovf, n_iovf, stack_cap=stack_cap,
                      frontier=frontier, max_iter=max_iter)
    # global top-k merge of the gathered per-shard candidates
    gd, gids = jax.lax.sort((gd, gids), num_keys=2)
    gd, gids = gd[:, :k], gids[:, :k]
    gids = jnp.where(gids == _ID_SENT, -1, gids)
    return gd, gids, nd
