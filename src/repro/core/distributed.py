"""Distributed metric search: a forest of shard-local trees under
``shard_map`` (DESIGN.md §2.5).

Scale-out model (matches production vector-search systems):
  * the dataset is sharded over the ``data`` mesh axis; each shard builds
    an independent local index (no cross-shard tree edges => no pointer
    chasing over ICI);
  * queries are replicated to every shard;
  * each shard runs the SAME jittable traversal as the single-device
    engine; per-shard fixed-size result buffers are merged with an
    all_gather; distance counts are psum-reduced (the global cost).

Shard-local ids are offset into the global id space host-side at build.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.tree.build import build_ght, build_mht
from repro.core.tree.flat import BinaryHyperplaneTree
from repro.core.tree.search import _search_binary


@dataclasses.dataclass
class ShardedForest:
    """Per-shard trees stacked on a leading shard axis, device-sharded."""
    trees: BinaryHyperplaneTree      # every leaf has leading dim = n_shards
    mesh: Mesh
    axis: str
    id_offset: Any                   # (n_shards,) global id offset per shard
    n_total: int


def _pad_tree(tree: BinaryHyperplaneTree, n_nodes: int, n_bucket: int
              ) -> BinaryHyperplaneTree:
    """Pad node/bucket arrays so every shard has identical shapes."""
    def pad1(a, target, fill):
        a = np.asarray(a)
        out = np.full((target,) + a.shape[1:], fill, a.dtype)
        out[:a.shape[0]] = a
        return out
    return BinaryHyperplaneTree(
        data=tree.data, perm=pad1(tree.perm, n_bucket, 0),
        p1=pad1(tree.p1, n_nodes, -1), p2=pad1(tree.p2, n_nodes, -1),
        d12=pad1(tree.d12, n_nodes, 0.0),
        p1_inherited=pad1(tree.p1_inherited, n_nodes, 0),
        cover_r1=pad1(tree.cover_r1, n_nodes, 0.0),
        cover_r2=pad1(tree.cover_r2, n_nodes, 0.0),
        left=pad1(tree.left, n_nodes, -1),
        right=pad1(tree.right, n_nodes, -1),
        leaf_start=pad1(tree.leaf_start, n_nodes, 0),
        leaf_count=pad1(tree.leaf_count, n_nodes, 0),
        norm_sq=tree.norm_sq,
    )


def build_forest(data: np.ndarray, metric_name: str, mesh: Mesh,
                 axis: str = "data", *, kind: str = "mht",
                 leaf_size: int = 32, seed: int = 0) -> ShardedForest:
    """Shard ``data`` over ``axis`` of ``mesh`` and build one local tree
    per shard (host-side), then device-put the stacked forest sharded on
    its leading axis."""
    n_shards = mesh.shape[axis]
    n = data.shape[0]
    per = (n + n_shards - 1) // n_shards
    builder = {"ght": build_ght, "mht": build_mht}[kind]
    trees, offsets = [], []
    for s in range(n_shards):
        lo, hi = s * per, min((s + 1) * per, n)
        shard_pts = data[lo:hi]
        if shard_pts.shape[0] == 0:
            shard_pts = data[:1]
            lo = 0
        trees.append(builder(shard_pts, metric_name,
                             leaf_size=leaf_size, seed=seed + s))
        offsets.append(lo)
    n_nodes = max(t.p1.shape[0] for t in trees)
    n_bucket = max(t.perm.shape[0] for t in trees)
    n_pts = max(t.data.shape[0] for t in trees)
    padded = []
    for t in trees:
        t = _pad_tree(t, n_nodes, n_bucket)
        dpad = np.zeros((n_pts, t.data.shape[1]), np.float32)
        dpad[:t.data.shape[0]] = t.data
        npad = np.zeros((n_pts,), np.float32)
        npad[:t.norm_sq.shape[0]] = t.norm_sq
        t = dataclasses.replace(t, data=dpad, norm_sq=npad)
        padded.append(t)
    stacked = jax.tree_util.tree_map(
        lambda *xs: np.stack(xs, axis=0), *padded)
    sharding = NamedSharding(mesh, P(axis))
    stacked = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, sharding), stacked)
    return ShardedForest(trees=stacked, mesh=mesh, axis=axis,
                         id_offset=jax.device_put(
                             jnp.asarray(offsets, jnp.int32)[:, None],
                             sharding),
                         n_total=n)


def forest_search(forest: ShardedForest, queries, t, *, metric_name: str,
                  mechanism: str = "hilbert", r_cap: int = 64,
                  stack_cap: int = 256, frontier: int = 8):
    """Replicated-query forest search.

    Returns (res_ids (Q, n_shards*r_cap) global ids, res_cnt (Q,),
    n_dist (Q,) summed over shards).
    """
    mesh, axis = forest.mesh, forest.axis
    leaf_cap = int(np.max(np.asarray(forest.trees.leaf_count)))
    queries = jnp.asarray(queries, jnp.float32)
    tq = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (queries.shape[0],))

    tree_specs = jax.tree_util.tree_map(lambda _: P(axis), forest.trees)

    @partial(shard_map, mesh=mesh,
             in_specs=(tree_specs, P(axis), P(), P()),
             out_specs=(P(None, axis), P(), P(), P(), P()),
             check_rep=False)
    def _run(tree, id_off, q, tt):
        # leading shard axis has local length 1 inside the map
        tree = jax.tree_util.tree_map(lambda x: x[0], tree)
        stats = _search_binary(
            tree, q, tt, metric_name=metric_name, mechanism=mechanism,
            r_cap=r_cap, stack_cap=stack_cap, leaf_cap=max(leaf_cap, 1),
            frontier=frontier, use_cover_radius=True)
        valid = stats.res_ids >= 0
        gids = jnp.where(valid, stats.res_ids + id_off[0, 0], -1)
        cnt = jax.lax.psum(stats.res_cnt, axis)
        nd = jax.lax.psum(stats.n_dist, axis)
        n_sovf = jax.lax.psum(
            jnp.sum(stats.stack_overflow.astype(jnp.int32)), axis)
        n_rovf = jax.lax.psum(
            jnp.sum(stats.overflow.astype(jnp.int32)), axis)
        return gids, cnt, nd, n_sovf, n_rovf

    gids, cnt, nd, n_sovf, n_rovf = _run(forest.trees, forest.id_offset,
                                         queries, tq)
    # exactness contract: a dropped stack entry or result slot means the
    # returned sets are silently truncated — refuse to return them
    if int(n_sovf):
        raise RuntimeError(
            f"forest_search: traversal stack overflow on {int(n_sovf)} "
            f"(query, shard) lanes — raise stack_cap (={stack_cap}) or "
            f"lower frontier (={frontier})")
    if int(n_rovf):
        raise RuntimeError(
            f"forest_search: result buffer overflow on {int(n_rovf)} "
            f"(query, shard) lanes — raise r_cap (={r_cap})")
    return gids, cnt, nd
