"""Exclusion predicates — the paper's contribution, as composable jnp ops.

All predicates answer: *given what we know about a query q, can an entire
region be excluded from the search?*  They are exact (never exclude a true
result) under their stated premises.

Hyperplane-partition setting (GHT/MHT/DiSAT): a node splits its points
into S_p1 = {s : d(s,p1) < d(s,p2)} and S_p2 = complement.  With
d1 = d(q,p1), d2 = d(q,p2), d12 = d(p1,p2), threshold t:

  hyperbolic  (any metric space; 3-embeddability in l2^2):
      (d1 - d2)/2 > t            =>  no solution in S_p1
  hilbert     (requires the four-point property; Theorems 1+2):
      (d1^2 - d2^2)/(2 d12) > t  =>  no solution in S_p1

Hilbert is strictly weaker (Appendix A: (a^2-b^2)/2c >= (a-b)/2 whenever
c <= a+b), so it excludes a superset of what hyperbolic excludes.

Ball/pivot setting: region R has cover radius r around pivot p; with
dp = d(q,p): exclude R iff dp > r + t (outside) or dp < r_low - t (inside
ring exclusion). These depend only on triangle inequality.

Sign convention: all functions return True where the region MAY BE
EXCLUDED. Batched over any leading shape.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray

_EPS = 1e-12


# ---------------------------------------------------------------------------
# hyperplane-partition exclusions
# ---------------------------------------------------------------------------

def hyperbolic_margin(d1: Array, d2: Array, d12: Array) -> Array:
    """Signed lower bound on d(s,·) separation: (d1-d2)/2.

    > t  =>  exclude the p1 side.  (d12 unused; kept for uniform signature.)
    """
    del d12
    return (d1 - d2) * 0.5


def hilbert_margin(d1: Array, d2: Array, d12: Array) -> Array:
    """Signed distance from (embedded) q to the bisector hyperplane of
    p1,p2: (d1^2 - d2^2) / (2 d12).   > t  =>  exclude the p1 side.

    Valid only when the metric has the four-point property (Theorem 2).

    d12 ~ 0 means the two pivots coincide and the bisector hyperplane is
    undefined: the margin is forced to 0 so NO exclusion can fire.  (This
    also defuses an XLA fusion hazard: for d1 == d2 the numerator is an
    exact 0 eagerly but an FMA-contracted ~1e-8 inside fused loops, which
    a ~0 denominator would otherwise amplify past any threshold.)
    """
    safe = d12 > 1e-6          # near-duplicate pivots: no usable bisector
    num = d1 * d1 - d2 * d2
    return jnp.where(safe, num / (2.0 * jnp.maximum(d12, _EPS)), 0.0)


def exclude_p1_side_hyperbolic(d1: Array, d2: Array, d12: Array,
                               t: Array) -> Array:
    return hyperbolic_margin(d1, d2, d12) > t


def exclude_p1_side_hilbert(d1: Array, d2: Array, d12: Array,
                            t: Array) -> Array:
    return hilbert_margin(d1, d2, d12) > t


def partition_exclusions(d1: Array, d2: Array, d12: Array, t: Array,
                         *, use_hilbert: bool) -> tuple[Array, Array]:
    """(exclude_left, exclude_right) for the S_p1 / S_p2 sides of a node.

    By symmetry the right side uses the margin with d1,d2 swapped.
    At most one side can be excluded for t >= 0 (margins are negatives of
    each other).
    """
    margin = hilbert_margin if use_hilbert else hyperbolic_margin
    m = margin(d1, d2, d12)
    return m > t, (-m) > t


# ---------------------------------------------------------------------------
# ball / pivot exclusions (cover radius) — used by MHT/DiSAT hybrids
# ---------------------------------------------------------------------------

def exclude_outside_ball(dp: Array, cover_r: Array, t: Array) -> Array:
    """Region within distance cover_r of pivot; q at dp: exclude iff the
    query ball cannot reach the cover ball."""
    return dp > cover_r + t


def exclude_inside_ring(dp: Array, inner_r: Array, t: Array) -> Array:
    """Region entirely OUTSIDE radius inner_r of pivot: exclude iff the
    query ball lies strictly inside."""
    return dp < inner_r - t


# ---------------------------------------------------------------------------
# capability gating
# ---------------------------------------------------------------------------

def margin_fn_for(metric, mechanism: str) -> Callable[[Array, Array, Array], Array]:
    """Resolve the margin function for a metric, enforcing the four-point
    requirement for 'hilbert'. mechanism in {'hyperbolic','hilbert'}."""
    if mechanism == "hyperbolic":
        return hyperbolic_margin
    if mechanism == "hilbert":
        if not metric.four_point_property:
            raise ValueError(
                f"metric {metric.name!r} lacks the four-point property; "
                "Hilbert Exclusion would be UNSOUND (paper §5.7). Use "
                "'hyperbolic', or an embeddable transform such as "
                "sqrt_manhattan.")
        return hilbert_margin
    raise ValueError(f"unknown mechanism {mechanism!r}")
