"""Intrinsic dimensionality + threshold calibration (paper §6.1, Table 2).

IDIM = mu^2 / (2 sigma^2) over sampled pairwise distances (Chavez et al.).
Thresholds t_n are calibrated so a ball query returns ~n results per 10^6
points — the paper derives them empirically; we use the n/10^6 quantile of
a query->data distance sample.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def idim_from_distances(d: Array) -> Array:
    """mu^2 / (2 sigma^2) of a flat sample of distances."""
    mu = jnp.mean(d)
    var = jnp.var(d)
    return (mu * mu) / (2.0 * jnp.maximum(var, 1e-24))


def rowwise_distance(metric, a: Array, b: Array) -> Array:
    """d(a_i, b_i) per row, memory-safe (never forms a pairwise block)."""
    return jax.vmap(lambda x, y: metric.pairwise(x[None], y[None])[0, 0])(a, b)


def sample_distances(metric, data: Array, n_pairs: int, key) -> Array:
    """Distances between n_pairs random (i, j) index pairs of ``data``."""
    n = data.shape[0]
    k1, k2 = jax.random.split(key)
    i = jax.random.randint(k1, (n_pairs,), 0, n)
    j = jax.random.randint(k2, (n_pairs,), 0, n)
    return rowwise_distance(metric, data[i], data[j])


def idim(metric, data: Array, key, n_pairs: int = 4096) -> Array:
    return idim_from_distances(sample_distances(metric, data, n_pairs, key))


def calibrate_thresholds(metric, data: Array, queries: Array,
                         ns=(1, 2, 4, 8, 16, 32),
                         block: int = 16384) -> dict[int, float]:
    """Table-2 style {n: t_n}: t_n = the (n/1e6) quantile of the
    query->data distance distribution, estimated over all q*N pairs,
    computed in data blocks to bound memory for the simplex metrics.
    """
    chunks = []
    n = data.shape[0]
    for start in range(0, n, block):
        chunks.append(metric.pairwise(queries, data[start:start + block])
                      .reshape(-1))
    d = jnp.concatenate(chunks)
    return {k: float(jnp.quantile(d, k / 1e6)) for k in ns}
