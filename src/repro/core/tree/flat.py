"""Flat structure-of-arrays tree containers (pytrees).

Pointer-free layouts so traversal is pure gathers — the TPU adaptation of
the paper's CPU pointer-chasing indexes (DESIGN.md §2).  All index arrays
are int32; -1 means "none".  Data is stored permuted so every leaf bucket
is a contiguous range; ``perm`` maps permuted position -> original id.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np


def _pytree(cls):
    """Register a dataclass of arrays as a jax pytree (all fields leaves)."""
    fields = [f.name for f in dataclasses.fields(cls)]

    def flatten(obj):
        return [getattr(obj, n) for n in fields], None

    def unflatten(_, leaves):
        return cls(*leaves)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


@_pytree
@dataclasses.dataclass
class BinaryHyperplaneTree:
    """GHT / MHT in flat form.

    Node i is INTERNAL iff left[i] >= 0 (a split node), else a LEAF holding
    the permuted-data range [leaf_start[i], leaf_start[i]+leaf_count[i]).

    Internal node fields:
      p1, p2        : permuted-data positions of the two pivots
      d12           : d(p1, p2), precomputed at build (Hilbert denominator)
      p1_inherited  : 1 if p1 is the parent's owning pivot (MHT) -> its
                      query distance is carried down, not recomputed
      cover_r1/2    : max distance from pivot k to any point in child k
                      (bisector-tree cover radii; paper §6.3 uses both
                      cover-radius and hyperplane exclusion)
      left, right   : child node ids (p1 side / p2 side)
      norm_sq       : per-point |x|^2 cache (DESIGN.md §3): lets the
                      gather-distance kernels skip recomputing row norms
                      for every gathered tile (euclidean/cosine MXU path)
    """
    data: Any          # (n, d) permuted points
    perm: Any          # (n,) permuted position -> original id
    p1: Any            # (m,) int32
    p2: Any            # (m,) int32
    d12: Any           # (m,) f32
    p1_inherited: Any  # (m,) int32 (0/1)
    cover_r1: Any      # (m,) f32
    cover_r2: Any      # (m,) f32
    left: Any          # (m,) int32
    right: Any         # (m,) int32
    leaf_start: Any    # (m,) int32
    leaf_count: Any    # (m,) int32
    norm_sq: Any       # (n,) f32

    @property
    def n_nodes(self) -> int:
        return int(self.p1.shape[0])

    @property
    def n_points(self) -> int:
        return int(self.data.shape[0])


@_pytree
@dataclasses.dataclass
class SATree:
    """Distal Spatial Approximation Tree (DiSAT) in flat CSR form.

    Every data point is exactly one node; node ids ARE permuted-data
    positions.  Node i has children child_ids[child_start[i] :
    child_start[i] + child_count[i]] (ordered as selected at build, i.e.
    distal order).

    cover_r[i]   : max d(i, x) over x in the subtree rooted at i
    d_parent[i]  : d(i, parent(i))  (root: 0) — Hilbert denominator when
                   the winning "sibling" is the parent node itself
    sib_off[i]   : offset into sib_d of node i's F_i x F_i sibling-distance
                   matrix, row-major with stride child_count[i]; -1 if no
                   children.  sib_d[sib_off[i] + a*F_i + b] = d(child_a,
                   child_b) — the build-time distances that Hilbert
                   Exclusion needs (paper footnote 1).
    """
    data: Any         # (n, d)
    perm: Any         # (n,)
    root: Any         # () int32
    child_start: Any  # (n,) int32
    child_count: Any  # (n,) int32
    child_ids: Any    # (total_children,) int32
    cover_r: Any      # (n,) f32
    d_parent: Any     # (n,) f32
    sib_off: Any      # (n,) int32
    sib_d: Any        # (total_sib_entries,) f32
    norm_sq: Any      # (n,) f32 per-point |x|^2 cache (DESIGN.md §3)

    @property
    def n_points(self) -> int:
        return int(self.data.shape[0])

    @property
    def max_fanout(self) -> int:
        return int(np.max(np.asarray(self.child_count)))
