"""Host-side index builders (numpy, vectorized per node).

Construction-time distances are free at query time (paper footnote 1): we
precompute pivot-pivot / sibling-sibling distances here and store them in
the flat containers.  ``data`` stays in ORIGINAL row order; leaf buckets
are ranges into a ``bucket_ids`` indirection array (named ``perm`` in the
containers), so search reports original ids directly.

Distance counting convention (matches the paper's cost model): only
query-to-object distances computed during search are counted; everything
computed here is amortised build cost.
"""

from __future__ import annotations

import numpy as np

from repro.core.tree.flat import BinaryHyperplaneTree, SATree

_EPS = 1e-12


def _np_pairwise(metric_name: str, x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Numpy mirror of repro.core.metrics pairwise kernels (float64)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    if metric_name in ("euclidean", "sqeuclidean"):
        xx = np.sum(x * x, -1)[:, None]
        yy = np.sum(y * y, -1)[None, :]
        d2 = np.maximum(xx + yy - 2.0 * (x @ y.T), 0.0)
        return d2 if metric_name == "sqeuclidean" else np.sqrt(d2)
    if metric_name == "cosine":
        xn = x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), _EPS)
        yn = y / np.maximum(np.linalg.norm(y, axis=-1, keepdims=True), _EPS)
        return np.sqrt(np.maximum(1.0 - np.clip(xn @ yn.T, -1, 1), 0.0))
    if metric_name == "angular":
        xn = x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), _EPS)
        yn = y / np.maximum(np.linalg.norm(y, axis=-1, keepdims=True), _EPS)
        return np.arccos(np.clip(xn @ yn.T, -1, 1)) / np.pi
    if metric_name == "jsd":
        def h(v):
            out = np.zeros_like(v)
            m = v > _EPS
            out[m] = -v[m] * np.log2(v[m])
            return out
        hx = np.sum(h(x), -1)[:, None]
        hy = np.sum(h(y), -1)[None, :]
        out = np.empty((x.shape[0], y.shape[0]))
        step = max(1, int(2**22 // max(1, y.shape[0] * y.shape[1])))
        for s in range(0, x.shape[0], step):
            xpy = x[s:s + step, None, :] + y[None, :, :]
            out[s:s + step] = np.sum(h(xpy), -1)
        jsdiv = 1.0 - 0.5 * (hx + hy - out)
        return np.sqrt(np.maximum(jsdiv, 0.0))
    if metric_name == "triangular":
        out = np.empty((x.shape[0], y.shape[0]))
        step = max(1, int(2**22 // max(1, y.shape[0] * y.shape[1])))
        for s in range(0, x.shape[0], step):
            diff2 = (x[s:s + step, None, :] - y[None, :, :]) ** 2
            den = x[s:s + step, None, :] + y[None, :, :]
            terms = np.where(den > _EPS, diff2 / np.maximum(den, _EPS), 0.0)
            out[s:s + step] = np.sum(terms, -1)
        return np.sqrt(np.maximum(out, 0.0))
    if metric_name == "manhattan":
        return np.sum(np.abs(x[:, None, :] - y[None, :, :]), -1)
    if metric_name == "sqrt_manhattan":
        return np.sqrt(np.sum(np.abs(x[:, None, :] - y[None, :, :]), -1))
    if metric_name == "chebyshev":
        return np.max(np.abs(x[:, None, :] - y[None, :, :]), -1)
    raise KeyError(metric_name)


def _one_to_many(metric_name: str, q: np.ndarray, x: np.ndarray) -> np.ndarray:
    return _np_pairwise(metric_name, q[None, :], x)[0]


def _norm_sq_cache(data: np.ndarray) -> np.ndarray:
    """Per-point |x|^2 in f32 — the gather-kernel norm cache.  Computed on
    the f32 rows exactly as the traversal would (same reduction input), so
    cached and on-the-fly norms agree."""
    d32 = np.asarray(data, np.float32)
    return np.add.reduce(d32 * d32, axis=-1, dtype=np.float32)


# ---------------------------------------------------------------------------
# GHT / MHT
# ---------------------------------------------------------------------------

class _NodeArrays:
    """Growable SoA node storage for the binary builders."""

    def __init__(self):
        self.p1, self.p2, self.d12 = [], [], []
        self.inh, self.cr1, self.cr2 = [], [], []
        self.left, self.right = [], []
        self.ls, self.lc = [], []

    def new(self) -> int:
        self.p1.append(-1); self.p2.append(-1); self.d12.append(0.0)
        self.inh.append(0); self.cr1.append(0.0); self.cr2.append(0.0)
        self.left.append(-1); self.right.append(-1)
        self.ls.append(0); self.lc.append(0)
        return len(self.p1) - 1


def _build_binary(data: np.ndarray, metric_name: str, *, monotonous: bool,
                  leaf_size: int, max_depth: int, seed: int
                  ) -> BinaryHyperplaneTree:
    """Shared GHT/MHT builder.

    GHT: p1 random, p2 = farthest-from-p1 (fresh per node).
    MHT: child inherits the parent pivot owning its subset as p1
    (monotone), selects only p2; search then reuses d(q, p1).
    """
    rng = np.random.default_rng(seed)
    n = data.shape[0]
    nodes = _NodeArrays()
    bucket_chunks: list[np.ndarray] = []
    bucket_pos = 0

    root = nodes.new()
    # worklist entries: (node, member original-ids, inherited pivot id or -1,
    #                    depth)
    work: list[tuple[int, np.ndarray, int, int]] = [
        (root, np.arange(n, dtype=np.int64), -1, 0)]

    while work:
        node, idx, inh_pivot, depth = work.pop()

        make_leaf = (idx.size <= leaf_size or depth >= max_depth
                     or (idx.size < 2 and inh_pivot < 0))
        if not make_leaf:
            # --- pivot selection -------------------------------------------
            # Random pivots (paper §6.2: "randomly select pairs of pivot
            # points").  Farthest-point p2 looks appealing but collapses
            # in high dims: nearly every point lands on the p1 side, the
            # depth cap then forces giant leaves.
            if monotonous and inh_pivot >= 0:
                p1 = int(inh_pivot)
                cand = idx
                inherited = 1
            else:
                p1 = int(idx[rng.integers(idx.size)])
                cand = idx[idx != p1]
                inherited = 0
            d_p1 = _one_to_many(metric_name, data[p1], data[cand])
            p2_local = int(rng.integers(cand.size))
            p2 = int(cand[p2_local])
            rest_mask = np.ones(cand.size, bool)
            rest_mask[p2_local] = False
            rest = cand[rest_mask]
            d1r = d_p1[rest_mask]
            d2r = _one_to_many(metric_name, data[p2], data[rest])
            go_left = d1r < d2r
            li, ri = rest[go_left], rest[~go_left]

            # extreme imbalance (< 5% on one side) degrades to linear
            # depth; the ball fallback below guarantees halving instead
            min_side = max(leaf_size, int(0.05 * rest.size))
            unbalanced = (rest.size > 4 * leaf_size
                          and min(li.size, ri.size) < min_side)

            if li.size == 0 or ri.size == 0 or unbalanced:
                # Degenerate hyperplane (every point on one side, e.g. p1
                # central + p2 extreme outlier).  An arbitrary re-split
                # would BREAK the hyperplane invariant and make both
                # exclusion mechanisms unsound.  Fall back to a BALL node:
                # p2 := p1, d12 := 0 so margins are identically 0 (never
                # exclude); cover radii stay valid for ANY assignment, so
                # we split by distance-to-p1 rank for balance.  The old p2
                # candidate rejoins the members (it is not a pivot here).
                order_ = np.argsort(d_p1, kind="stable")
                half = cand.size // 2
                li, ri = cand[order_[:half]], cand[order_[half:]]
                nodes.p1[node], nodes.p2[node] = p1, p1
                nodes.d12[node] = 0.0
                nodes.inh[node] = inherited
                nodes.cr1[node] = float(d_p1[order_[:half]].max()) \
                    if li.size else 0.0
                nodes.cr2[node] = float(d_p1[order_[half:]].max()) \
                    if ri.size else 0.0
                lnode, rnode = nodes.new(), nodes.new()
                nodes.left[node], nodes.right[node] = lnode, rnode
                inh_b = p1 if monotonous else -1
                work.append((lnode, li, inh_b, depth + 1))
                work.append((rnode, ri, inh_b, depth + 1))
                continue

            nodes.p1[node], nodes.p2[node] = p1, p2
            nodes.d12[node] = float(
                _one_to_many(metric_name, data[p1], data[p2][None, :])[0])
            nodes.inh[node] = inherited
            nodes.cr1[node] = float(d1r[go_left].max()) if li.size else 0.0
            nodes.cr2[node] = float(d2r[~go_left].max()) if ri.size else 0.0
            lnode, rnode = nodes.new(), nodes.new()
            nodes.left[node], nodes.right[node] = lnode, rnode
            inh_l = p1 if monotonous else -1
            inh_r = p2 if monotonous else -1
            work.append((lnode, li, inh_l, depth + 1))
            work.append((rnode, ri, inh_r, depth + 1))
            continue

        # --- leaf -----------------------------------------------------------
        nodes.ls[node] = bucket_pos
        nodes.lc[node] = int(idx.size)
        bucket_chunks.append(idx.astype(np.int32))
        bucket_pos += int(idx.size)

    bucket_ids = (np.concatenate(bucket_chunks).astype(np.int32)
                  if bucket_chunks else np.zeros((0,), np.int32))
    return BinaryHyperplaneTree(
        data=np.asarray(data, np.float32),
        perm=bucket_ids,
        p1=np.asarray(nodes.p1, np.int32),
        p2=np.asarray(nodes.p2, np.int32),
        d12=np.asarray(nodes.d12, np.float32),
        p1_inherited=np.asarray(nodes.inh, np.int32),
        cover_r1=np.asarray(nodes.cr1, np.float32),
        cover_r2=np.asarray(nodes.cr2, np.float32),
        left=np.asarray(nodes.left, np.int32),
        right=np.asarray(nodes.right, np.int32),
        leaf_start=np.asarray(nodes.ls, np.int32),
        leaf_count=np.asarray(nodes.lc, np.int32),
        norm_sq=_norm_sq_cache(data),
    )


def build_ght(data, metric_name: str, *, leaf_size: int = 32,
              max_depth: int = 64, seed: int = 0) -> BinaryHyperplaneTree:
    """Generalised Hyperplane Tree (Uhlmann 1991)."""
    return _build_binary(np.asarray(data), metric_name, monotonous=False,
                         leaf_size=leaf_size, max_depth=max_depth, seed=seed)


def build_mht(data, metric_name: str, *, leaf_size: int = 32,
              max_depth: int = 64, seed: int = 0) -> BinaryHyperplaneTree:
    """Monotonous Hyperplane (Bisector*) Tree (Noltemeier et al. 1992)."""
    return _build_binary(np.asarray(data), metric_name, monotonous=True,
                         leaf_size=leaf_size, max_depth=max_depth, seed=seed)


# ---------------------------------------------------------------------------
# DiSAT
# ---------------------------------------------------------------------------

def build_disat(data, metric_name: str, *, seed: int = 0,
                distal: bool = True) -> SATree:
    """Distal Spatial Approximation Tree (Chavez et al. 2014/2016).

    Neighbour selection processes candidates in DECREASING distance from
    the node (``distal=True``); v joins N(a) iff it is closer to a than to
    every already-accepted neighbour, else it falls into the bag of its
    closest neighbour.  ``distal=False`` gives the classic SAT order.

    Greedy loop is O(|S|) python per node with O(|N|) vectorised rows;
    sibling pairwise distances are stored for Hilbert Exclusion.
    """
    data = np.asarray(data)
    rng = np.random.default_rng(seed)
    n = data.shape[0]

    child_start = np.full(n, -1, np.int64)
    child_count = np.zeros(n, np.int64)
    child_ids_chunks: list[np.ndarray] = []
    child_pos = 0
    cover_r = np.zeros(n, np.float64)
    d_parent = np.zeros(n, np.float64)
    sib_off = np.full(n, -1, np.int64)
    sib_chunks: list[np.ndarray] = []
    sib_pos = 0

    root = int(rng.integers(n))
    work: list[tuple[int, np.ndarray]] = [
        (root, np.setdiff1d(np.arange(n, dtype=np.int64), [root]))]

    while work:
        a, members = work.pop()
        if members.size == 0:
            child_start[a] = 0
            child_count[a] = 0
            continue
        d_a = _one_to_many(metric_name, data[a], data[members])
        order = np.argsort(-d_a if distal else d_a, kind="stable")
        members = members[order]
        d_a = d_a[order]
        cover_r[a] = float(d_a.max())

        m = members.size
        dmin = np.full(m, np.inf)           # distance to closest neighbour
        amin = np.full(m, -1, np.int64)     # local index of that neighbour
        neigh: list[int] = []               # local indices into members
        for v in range(m):
            if d_a[v] < dmin[v]:
                # v becomes a new neighbour of a
                nb_local = len(neigh)
                neigh.append(v)
                d_v = _one_to_many(metric_name, data[members[v]],
                                   data[members])
                upd = d_v < dmin
                dmin = np.where(upd, d_v, dmin)
                amin = np.where(upd, nb_local, amin)
                dmin[v] = 0.0               # a neighbour belongs to itself
                amin[v] = nb_local
        neigh_arr = np.asarray(neigh, np.int64)
        f = neigh_arr.size
        cids = members[neigh_arr]

        child_start[a] = child_pos
        child_count[a] = f
        child_ids_chunks.append(cids.astype(np.int32))
        child_pos += f
        d_parent[cids] = d_a[neigh_arr]

        # sibling pairwise distances (build-time, free at query); zero
        # the diagonal EXACTLY — matmul-trick noise (~1e-7) there would
        # defeat the degenerate-denominator guard at query time
        sib = _np_pairwise(metric_name, data[cids], data[cids])
        np.fill_diagonal(sib, 0.0)
        sib_off[a] = sib_pos
        sib_chunks.append(sib.reshape(-1).astype(np.float32))
        sib_pos += f * f

        # bags: every non-neighbour member belongs to amin's bag
        for nb_local in range(f):
            bag_mask = amin == nb_local
            bag_mask[neigh_arr[nb_local]] = False
            bag = members[bag_mask]
            work.append((int(cids[nb_local]), bag))

    child_ids = (np.concatenate(child_ids_chunks).astype(np.int32)
                 if child_ids_chunks else np.zeros((0,), np.int32))
    sib_d = (np.concatenate(sib_chunks).astype(np.float32)
             if sib_chunks else np.zeros((0,), np.float32))
    return SATree(
        data=np.asarray(data, np.float32),
        perm=np.arange(n, dtype=np.int32),
        root=np.int32(root),
        child_start=child_start.astype(np.int32),
        child_count=child_count.astype(np.int32),
        child_ids=child_ids,
        cover_r=cover_r.astype(np.float32),
        d_parent=d_parent.astype(np.float32),
        sib_off=sib_off.astype(np.int32),
        sib_d=sib_d,
        norm_sq=_norm_sq_cache(data),
    )
