from repro.core.tree.flat import BinaryHyperplaneTree, SATree
from repro.core.tree.build import build_ght, build_mht, build_disat
from repro.core.tree.search import (
    search_binary_tree, search_sat, knn_search_binary_tree, knn_search_sat,
    SearchStats, KnnStats, check_complete)

__all__ = [
    "BinaryHyperplaneTree", "SATree",
    "build_ght", "build_mht", "build_disat",
    "search_binary_tree", "search_sat",
    "knn_search_binary_tree", "knn_search_sat",
    "SearchStats", "KnnStats", "check_complete",
]
