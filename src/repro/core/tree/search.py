"""Jittable frontier-batched tree traversal with selectable exclusion.

Both engines run all query lanes in lockstep around a width-B *frontier*
(DESIGN.md §3): each ``lax.while_loop`` iteration pops up to ``frontier``
nodes per lane, gathers all their pivots / children / leaf points into
ONE dense (Q, tile, d) block, evaluates every query-to-object distance
in a single fused ``block_distance`` call (the paper's unit of cost —
counted exactly), applies the selected exclusion (hyperbolic / hilbert)
plus cover-radius exclusion vectorized over the whole frontier, and
multi-pushes all surviving children.  Lanes with short stacks pop fewer
nodes (masked); empty lanes idle.

Because every exclusion decision depends only on local geometry (the
query's distances to one node's pivots), the visited-node set — and
therefore the result set and the per-query distance count — is invariant
to pop order and frontier width.  ``frontier=1`` IS the classic one-node-
per-iteration engine; the parity tests assert B>1 reproduces it exactly.

Exact range search: for the same (tree, queries, t) every mechanism must
return the identical result set (paper §6.5); tests assert this.

Exact k-NN search (DESIGN.md §8): the same frontier machinery run
best-first with a *shrinking* radius t = current k-th best distance per
lane (Connor et al., "Supermetric Search", arXiv 1707.08361 generalise
the four-point bounds beyond fixed-radius queries).  Each lane keeps a
sorted (k,) best-distance/best-id buffer in the while-loop carry; every
stack entry carries the lower-bound margin it survived at push time so a
popped node is RE-TESTED against the now-smaller radius before its tile
is evaluated.  Unlike range search, per-query ``n_dist`` is legitimately
order-sensitive for k-NN (frontier width B changes cost) but the
returned k-set — ties broken by (distance, id) — never changes.

Static jit arguments: metric name, mechanism, buffer sizes, frontier
width.  The tree is a dynamic pytree operand, so one compilation serves
every tree of the same shape.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exclusion as excl
from repro.core import metrics as metrics_lib
from repro.core.blockdist import block_distance, one_distance
from repro.core.tree.flat import BinaryHyperplaneTree, SATree

Array = jnp.ndarray

_I32 = jnp.int32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SearchStats:
    """Per-query search outcome.

    res_ids:  (Q, R) original data ids of results (first res_cnt valid,
              capped at R; overflow flags truncation)
    res_cnt:  (Q,) true number of results (may exceed R)
    n_dist:   (Q,) query-to-object distance evaluations (the paper's cost)
    overflow: (Q,) result buffer overflow
    stack_overflow: (Q,) traversal stack overflow (correctness violated if
              set — sized so tests prove it never fires)
    iter_overflow: (Q,) the while_loop hit max_iter with this lane's stack
              non-empty: the result set is silently TRUNCATED (correctness
              violated if set; callers must refuse to use the results)
    iters:    () loop iterations executed (each evaluates one frontier)
    """
    res_ids: Any
    res_cnt: Any
    n_dist: Any
    overflow: Any
    stack_overflow: Any
    iter_overflow: Any
    iters: Any

    def tree_flatten(self):
        return ((self.res_ids, self.res_cnt, self.n_dist, self.overflow,
                 self.stack_overflow, self.iter_overflow, self.iters), None)

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)

    def result_sets(self) -> list[set[int]]:
        """Host-side: per-query sets of result ids (requires no overflow)."""
        ids = np.asarray(self.res_ids)
        cnt = np.asarray(self.res_cnt)
        return [set(ids[i, :min(int(cnt[i]), ids.shape[1])].tolist())
                for i in range(ids.shape[0])]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KnnStats:
    """Per-query k-NN search outcome.

    ids:   (Q, k) original data ids, ascending (distance, id); -1 pads
           slots beyond n when k > n
    dists: (Q, k) matching distances (+inf in padded slots)
    n_dist: (Q,) query-to-object distance evaluations (order-sensitive
           for k-NN: frontier width changes cost, never the k-set)
    stack_overflow: (Q,) traversal stack overflow (correctness violated)
    iter_overflow:  (Q,) loop ended at max_iter with a non-empty stack
           (results silently truncated; callers must refuse them)
    iters: () loop iterations executed
    """
    ids: Any
    dists: Any
    n_dist: Any
    stack_overflow: Any
    iter_overflow: Any
    iters: Any

    def tree_flatten(self):
        return ((self.ids, self.dists, self.n_dist, self.stack_overflow,
                 self.iter_overflow, self.iters), None)

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)


def check_complete(stats, *, context: str = "search") -> None:
    """Refuse silently wrong result sets: raise if any lane overflowed its
    stack, its result buffer, or the iteration budget.  Mirrors the
    forest_search refusal for single-tree callers (serve/benchmarks)."""
    if np.asarray(stats.stack_overflow).any():
        raise RuntimeError(
            f"{context}: traversal stack overflow — raise stack_cap or "
            "lower frontier")
    if getattr(stats, "overflow", None) is not None and \
            np.asarray(stats.overflow).any():
        raise RuntimeError(f"{context}: result buffer overflow — raise "
                           "r_cap")
    if np.asarray(stats.iter_overflow).any():
        raise RuntimeError(
            f"{context}: iteration budget exhausted with non-empty "
            "stacks — results would be silently truncated; raise "
            "max_iter")


def _margin(mechanism: str, d1: Array, d2: Array, d12: Array) -> Array:
    if mechanism == "hyperbolic":
        return excl.hyperbolic_margin(d1, d2, d12)
    if mechanism == "hilbert":
        return excl.hilbert_margin(d1, d2, d12)
    raise ValueError(f"unknown mechanism {mechanism!r}")


def _check_mechanism(metric_name: str, mechanism: str) -> None:
    metric = metrics_lib.get(metric_name)
    excl.margin_fn_for(metric, mechanism)  # raises if unsound


def _append_results(res_ids, res_cnt, overflow, lane, ids, hits, r_cap):
    """Append up to W hits per lane into the fixed (Q, R) buffer."""
    pos = res_cnt[:, None] + jnp.cumsum(hits.astype(_I32), axis=1) - 1
    ok = hits & (pos < r_cap)
    wpos = jnp.where(ok, pos, r_cap)              # r_cap column == dropped
    res_ids = res_ids.at[lane[:, None], wpos].set(
        ids.astype(_I32), mode="drop")
    res_cnt = res_cnt + jnp.sum(hits, axis=1).astype(_I32)
    overflow = overflow | (res_cnt > r_cap)
    return res_ids, res_cnt, overflow


def _pop_frontier(stack_n, payloads, sp, b_cap: int, stack_cap: int):
    """Pop up to ``b_cap`` nodes per lane off the stack tops.

    ``payloads`` is a tuple of (Q, S) per-entry side arrays (carried
    distance, push-time margin, ...) popped in lockstep with the node
    stack.  Returns (node (Q, B), popped payload tuple, fvalid (Q, B),
    new sp).  Slot j holds the j-th-from-top entry; invalid slots are
    clamped to node 0 and must be masked via fvalid.
    """
    j = jnp.arange(b_cap, dtype=_I32)[None, :]
    npop = jnp.minimum(sp, b_cap)
    fvalid = j < npop[:, None]
    pos = jnp.clip(sp[:, None] - 1 - j, 0, max(stack_cap - 1, 0))
    node = jnp.take_along_axis(stack_n, pos, 1)
    popped = tuple(jnp.take_along_axis(p, pos, 1) for p in payloads)
    node = jnp.where(fvalid, node, 0)
    return node, popped, fvalid, sp - npop


def _multi_push(stack_n, payloads, sp, stack_ovf, lane, nodes, values,
                mask, stack_cap: int):
    """Push masked (Q, W) candidates; candidate order = push order, so
    later columns end nearer the stack top.  ``payloads``/``values`` are
    matching tuples of side stacks / per-candidate side values."""
    pos = sp[:, None] + jnp.cumsum(mask.astype(_I32), axis=1) - 1
    wpos = jnp.where(mask, pos, stack_cap)        # stack_cap col == dropped
    stack_n = stack_n.at[lane[:, None], wpos].set(nodes, mode="drop")
    payloads = tuple(
        p.at[lane[:, None], wpos].set(v, mode="drop")
        for p, v in zip(payloads, values))
    sp = sp + jnp.sum(mask, axis=1).astype(_I32)
    stack_ovf = stack_ovf | (sp > stack_cap)
    return stack_n, payloads, sp, stack_ovf


# ---------------------------------------------------------------------------
# binary (GHT / MHT)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("metric_name", "mechanism", "r_cap",
                              "stack_cap", "leaf_cap", "frontier",
                              "use_cover_radius", "max_iter"))
def _search_binary(tree: BinaryHyperplaneTree, queries: Array, t: Array,
                   *, metric_name: str, mechanism: str, r_cap: int,
                   stack_cap: int, leaf_cap: int, frontier: int = 1,
                   use_cover_radius: bool,
                   max_iter: int | None = None) -> SearchStats:
    nq = queries.shape[0]
    n = tree.data.shape[0]
    b_cap = frontier
    lane = jnp.arange(nq, dtype=_I32)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (nq,))

    stack_n = jnp.zeros((nq, stack_cap), _I32)          # root = node 0
    stack_d = jnp.zeros((nq, stack_cap), jnp.float32)
    sp = jnp.ones((nq,), _I32)
    res_ids = jnp.full((nq, r_cap + 1), -1, _I32)       # +1 drop column
    res_cnt = jnp.zeros((nq,), _I32)
    n_dist = jnp.zeros((nq,), _I32)
    overflow = jnp.zeros((nq,), bool)
    stack_ovf = jnp.zeros((nq,), bool)
    if max_iter is None:
        max_iter = tree.p1.shape[0] + 8                  # ≤ nodes visited

    def cond(st):
        (_, _, sp, _, _, _, _, _, it) = st
        return jnp.any(sp > 0) & (it < max_iter)

    def body(st):
        (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
         stack_ovf, it) = st
        node, (carried,), fvalid, sp = _pop_frontier(
            stack_n, (stack_d,), sp, b_cap, stack_cap)  # all (Q, B)

        left = tree.left[node]
        right = tree.right[node]
        is_int = (left >= 0) & fvalid
        is_leaf = (left < 0) & fvalid

        # ---- frontier gather: pivots + leaf buckets as ONE dense tile --
        p1 = tree.p1[node]                               # (Q, B)
        p2 = tree.p2[node]
        d12 = tree.d12[node]
        inh = tree.p1_inherited[node] == 1
        same_pivot = p1 == p2                            # ball-fallback node
        start = tree.leaf_start[node]
        cnt = tree.leaf_count[node]
        lcols = jnp.arange(leaf_cap, dtype=_I32)[None, None, :]
        lmask = is_leaf[:, :, None] & (lcols < cnt[:, :, None])  # (Q, B, L)
        bslot = jnp.clip(start[:, :, None] + lcols, 0,
                         jnp.maximum(tree.perm.shape[0] - 1, 0))
        bidx = tree.perm[bslot] if tree.perm.shape[0] else \
            jnp.zeros((nq, b_cap, leaf_cap), _I32)

        tile_idx = jnp.concatenate(
            [jnp.clip(p1, 0, n - 1), jnp.clip(p2, 0, n - 1),
             bidx.reshape(nq, b_cap * leaf_cap)], axis=1)
        dtile = block_distance(
            metric_name, queries, tree.data[tile_idx],
            pts_norm_sq=tree.norm_sq[tile_idx])          # (Q, B(2+L))
        d1f = dtile[:, :b_cap]
        d2c = dtile[:, b_cap:2 * b_cap]
        dl = dtile[:, 2 * b_cap:].reshape(nq, b_cap, leaf_cap)

        # ---- internal nodes -------------------------------------------
        d1 = jnp.where(inh, carried, d1f)
        d2 = jnp.where(same_pivot, d1, d2c)
        # fresh distances: p1 unless inherited, p2 unless it IS p1
        n_dist = n_dist + jnp.sum(jnp.where(
            is_int,
            (1 - inh.astype(_I32)) + (1 - same_pivot.astype(_I32)),
            0), axis=1)
        tq = t[:, None]
        hit_p1 = is_int & ~inh & (d1f <= tq)
        hit_p2 = is_int & ~same_pivot & (d2 <= tq)

        m = _margin(mechanism, d1, d2, d12)
        excl_l = m > tq
        excl_r = (-m) > tq
        if use_cover_radius:
            excl_l = excl_l | (d1 > tree.cover_r1[node] + tq)
            excl_r = excl_r | (d2 > tree.cover_r2[node] + tq)
        push_l = is_int & ~excl_l
        push_r = is_int & ~excl_r

        # ---- leaves ----------------------------------------------------
        n_dist = n_dist + jnp.sum(lmask, axis=(1, 2)).astype(_I32)
        lhit = lmask & (dl <= tq[:, :, None])

        # ---- results ---------------------------------------------------
        ids = jnp.concatenate(
            [p1, p2, bidx.reshape(nq, b_cap * leaf_cap)], axis=1)
        hms = jnp.concatenate(
            [hit_p1, hit_p2, lhit.reshape(nq, b_cap * leaf_cap)], axis=1)
        res_ids, res_cnt, overflow = _append_results(
            res_ids, res_cnt, overflow, lane, ids, hms, r_cap)

        # ---- multi-push ------------------------------------------------
        # Frontier slot 0 was the stack top: flip so ITS children are
        # pushed last (back on top), keeping depth-first stack growth;
        # within a node, right before left => left explored first.
        cand_n = jnp.flip(jnp.stack([right, left], 2), 1).reshape(nq, -1)
        cand_d = jnp.flip(jnp.stack([d2, d1], 2), 1).reshape(nq, -1)
        cand_m = jnp.flip(jnp.stack([push_r, push_l], 2), 1).reshape(nq, -1)
        stack_n, (stack_d,), sp, stack_ovf = _multi_push(
            stack_n, (stack_d,), sp, stack_ovf, lane, cand_n, (cand_d,),
            cand_m, stack_cap)

        return (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
                stack_ovf, it + 1)

    init = (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
            stack_ovf, jnp.zeros((), _I32))
    (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow, stack_ovf,
     it) = jax.lax.while_loop(cond, body, init)
    return SearchStats(res_ids[:, :r_cap], res_cnt, n_dist, overflow,
                       stack_ovf, sp > 0, it)


def search_binary_tree(tree: BinaryHyperplaneTree, queries, t, *,
                       metric_name: str, mechanism: str = "hilbert",
                       r_cap: int = 128, stack_cap: int = 256,
                       frontier: int = 8, use_cover_radius: bool = True,
                       max_iter: int | None = None) -> SearchStats:
    """Range search on a GHT/MHT.  mechanism in {'hyperbolic','hilbert'}.

    ``frontier``: nodes popped per lane per iteration (static).  Any
    B >= 1 returns the identical result set and identical per-query
    ``n_dist``; larger B cuts loop trip count ~B× and widens each
    distance tile by the same factor (DESIGN.md §3).  ``stack_cap``
    (default 256) must absorb the extra in-flight breadth; the
    ``stack_overflow`` flag reports violations.  ``max_iter`` (default
    n_nodes + 8, which provably suffices) bounds the while_loop; ending
    with non-empty stacks sets ``iter_overflow`` — truncated results
    that callers must refuse (``check_complete``).
    """
    _check_mechanism(metric_name, mechanism)
    if frontier < 1:
        raise ValueError(f"frontier must be >= 1, got {frontier}")
    leaf_cap = int(np.max(np.asarray(tree.leaf_count))) if \
        tree.leaf_count.shape[0] else 1
    tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return _search_binary(
        tree, jnp.asarray(queries, jnp.float32), t,
        metric_name=metric_name, mechanism=mechanism, r_cap=r_cap,
        stack_cap=stack_cap, leaf_cap=max(leaf_cap, 1), frontier=frontier,
        use_cover_radius=use_cover_radius, max_iter=max_iter)


_ID_SENT = np.int32(np.iinfo(np.int32).max)   # sorts after every real id


def _merge_best(best_d, best_i, cand_d, cand_i, cand_ok, k: int):
    """Merge masked candidates into the sorted (Q, k) best buffer.

    Ordering key is (distance, id) — ties at the k-boundary resolve to
    the smallest ids, matching ``lax.top_k``'s lower-index tie rule in
    ``bruteforce.knn``, and making the k-set independent of traversal
    order / frontier width.
    """
    cand_d = jnp.where(cand_ok, cand_d, jnp.inf)
    cand_i = jnp.where(cand_ok, cand_i, _ID_SENT)
    md = jnp.concatenate([best_d, cand_d], axis=1)
    mi = jnp.concatenate([best_i, cand_i], axis=1)
    md, mi = jax.lax.sort((md, mi), num_keys=2)
    return md[:, :k], mi[:, :k]


@functools.partial(
    jax.jit, static_argnames=("metric_name", "mechanism", "k", "stack_cap",
                              "leaf_cap", "frontier", "use_cover_radius",
                              "max_iter"))
def _knn_binary(tree: BinaryHyperplaneTree, queries: Array, *,
                metric_name: str, mechanism: str, k: int, stack_cap: int,
                leaf_cap: int, frontier: int = 1, use_cover_radius: bool,
                max_iter: int | None = None) -> KnnStats:
    nq = queries.shape[0]
    n = tree.data.shape[0]
    b_cap = frontier
    lane = jnp.arange(nq, dtype=_I32)

    stack_n = jnp.zeros((nq, stack_cap), _I32)          # root = node 0
    stack_d = jnp.zeros((nq, stack_cap), jnp.float32)
    stack_m = jnp.full((nq, stack_cap), -jnp.inf, jnp.float32)
    sp = jnp.ones((nq,), _I32)
    best_d = jnp.full((nq, k), jnp.inf, jnp.float32)
    best_i = jnp.full((nq, k), _ID_SENT, _I32)
    n_dist = jnp.zeros((nq,), _I32)
    stack_ovf = jnp.zeros((nq,), bool)
    if max_iter is None:
        max_iter = tree.p1.shape[0] + 8                  # ≤ nodes visited

    def cond(st):
        (_, _, _, sp, _, _, _, _, it) = st
        return jnp.any(sp > 0) & (it < max_iter)

    def body(st):
        (stack_n, stack_d, stack_m, sp, best_d, best_i, n_dist,
         stack_ovf, it) = st
        node, (carried, pmargin), fvalid, sp = _pop_frontier(
            stack_n, (stack_d, stack_m), sp, b_cap, stack_cap)

        # best-first re-test: the radius may have shrunk below the lower
        # bound this entry survived at push time — drop it before paying
        # for its tile (most of the win over naive traversal)
        t_pop = best_d[:, -1]
        fvalid = fvalid & ~(pmargin > t_pop[:, None])
        node = jnp.where(fvalid, node, 0)

        left = tree.left[node]
        right = tree.right[node]
        is_int = (left >= 0) & fvalid
        is_leaf = (left < 0) & fvalid

        # ---- frontier gather: pivots + leaf buckets as ONE dense tile --
        p1 = tree.p1[node]                               # (Q, B)
        p2 = tree.p2[node]
        d12 = tree.d12[node]
        inh = tree.p1_inherited[node] == 1
        same_pivot = p1 == p2                            # ball-fallback node
        start = tree.leaf_start[node]
        cnt = tree.leaf_count[node]
        lcols = jnp.arange(leaf_cap, dtype=_I32)[None, None, :]
        lmask = is_leaf[:, :, None] & (lcols < cnt[:, :, None])  # (Q, B, L)
        bslot = jnp.clip(start[:, :, None] + lcols, 0,
                         jnp.maximum(tree.perm.shape[0] - 1, 0))
        bidx = tree.perm[bslot] if tree.perm.shape[0] else \
            jnp.zeros((nq, b_cap, leaf_cap), _I32)

        tile_idx = jnp.concatenate(
            [jnp.clip(p1, 0, n - 1), jnp.clip(p2, 0, n - 1),
             bidx.reshape(nq, b_cap * leaf_cap)], axis=1)
        dtile = block_distance(
            metric_name, queries, tree.data[tile_idx],
            pts_norm_sq=tree.norm_sq[tile_idx])          # (Q, B(2+L))
        d1f = dtile[:, :b_cap]
        d2c = dtile[:, b_cap:2 * b_cap]
        dl = dtile[:, 2 * b_cap:].reshape(nq, b_cap, leaf_cap)

        d1 = jnp.where(inh, carried, d1f)
        d2 = jnp.where(same_pivot, d1, d2c)
        # fresh distances: p1 unless inherited, p2 unless it IS p1
        n_dist = n_dist + jnp.sum(jnp.where(
            is_int,
            (1 - inh.astype(_I32)) + (1 - same_pivot.astype(_I32)),
            0), axis=1)
        n_dist = n_dist + jnp.sum(lmask, axis=(1, 2)).astype(_I32)

        # ---- candidates -> best buffer; THEN the shrunk radius --------
        fresh1 = is_int & ~inh
        fresh2 = is_int & ~same_pivot
        cand_i = jnp.concatenate(
            [p1, p2, bidx.reshape(nq, b_cap * leaf_cap)], axis=1)
        cand_d = jnp.concatenate(
            [d1f, d2, dl.reshape(nq, b_cap * leaf_cap)], axis=1)
        cand_ok = jnp.concatenate(
            [fresh1, fresh2, lmask.reshape(nq, b_cap * leaf_cap)], axis=1)
        best_d, best_i = _merge_best(best_d, best_i, cand_d, cand_i,
                                     cand_ok, k)
        tq = best_d[:, -1][:, None]                      # k-th best NOW

        # ---- children: lower bounds against the shrunk radius ---------
        m = _margin(mechanism, d1, d2, d12)
        lb_l, lb_r = m, -m
        if use_cover_radius:
            lb_l = jnp.maximum(lb_l, d1 - tree.cover_r1[node])
            lb_r = jnp.maximum(lb_r, d2 - tree.cover_r2[node])
        push_l = is_int & ~(lb_l > tq)
        push_r = is_int & ~(lb_r > tq)

        # ---- multi-push, nearer child last => popped first ------------
        # (priority-ordered descent shrinks the radius fast); frontier
        # flip keeps depth-first growth exactly as in range search.
        l_near = d1 <= d2
        far_n = jnp.where(l_near, right, left)
        near_n = jnp.where(l_near, left, right)
        far_d = jnp.where(l_near, d2, d1)
        near_d = jnp.where(l_near, d1, d2)
        far_m = jnp.where(l_near, lb_r, lb_l)
        near_m = jnp.where(l_near, lb_l, lb_r)
        far_p = jnp.where(l_near, push_r, push_l)
        near_p = jnp.where(l_near, push_l, push_r)
        cand_n = jnp.flip(jnp.stack([far_n, near_n], 2), 1).reshape(nq, -1)
        cand_d = jnp.flip(jnp.stack([far_d, near_d], 2), 1).reshape(nq, -1)
        cand_m = jnp.flip(jnp.stack([far_m, near_m], 2), 1).reshape(nq, -1)
        cand_p = jnp.flip(jnp.stack([far_p, near_p], 2), 1).reshape(nq, -1)
        stack_n, (stack_d, stack_m), sp, stack_ovf = _multi_push(
            stack_n, (stack_d, stack_m), sp, stack_ovf, lane, cand_n,
            (cand_d, cand_m), cand_p, stack_cap)

        return (stack_n, stack_d, stack_m, sp, best_d, best_i, n_dist,
                stack_ovf, it + 1)

    init = (stack_n, stack_d, stack_m, sp, best_d, best_i, n_dist,
            stack_ovf, jnp.zeros((), _I32))
    (stack_n, stack_d, stack_m, sp, best_d, best_i, n_dist, stack_ovf,
     it) = jax.lax.while_loop(cond, body, init)
    ids = jnp.where(best_i == _ID_SENT, -1, best_i)
    return KnnStats(ids, best_d, n_dist, stack_ovf, sp > 0, it)


def knn_search_binary_tree(tree: BinaryHyperplaneTree, queries, k: int, *,
                           metric_name: str, mechanism: str = "hilbert",
                           stack_cap: int = 256, frontier: int = 8,
                           use_cover_radius: bool = True,
                           max_iter: int | None = None) -> KnnStats:
    """Exact k-NN on a GHT/MHT via best-first shrinking-radius traversal.

    Returns ids/distances ascending by (distance, id) — identical to
    ``bruteforce.knn`` including k-boundary ties; slots beyond n (when
    k > n) hold (-1, +inf).  ``frontier`` changes ``n_dist`` (the radius
    shrinks at frontier granularity) but never the k-set.
    """
    _check_mechanism(metric_name, mechanism)
    if frontier < 1:
        raise ValueError(f"frontier must be >= 1, got {frontier}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    leaf_cap = int(np.max(np.asarray(tree.leaf_count))) if \
        tree.leaf_count.shape[0] else 1
    tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return _knn_binary(
        tree, jnp.asarray(queries, jnp.float32),
        metric_name=metric_name, mechanism=mechanism, k=k,
        stack_cap=stack_cap, leaf_cap=max(leaf_cap, 1), frontier=frontier,
        use_cover_radius=use_cover_radius, max_iter=max_iter)


# ---------------------------------------------------------------------------
# DiSAT
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("metric_name", "mechanism", "r_cap",
                              "stack_cap", "fan_cap", "frontier",
                              "use_cover_radius", "max_iter"))
def _search_sat(tree: SATree, queries: Array, t: Array, *,
                metric_name: str, mechanism: str, r_cap: int,
                stack_cap: int, fan_cap: int, frontier: int = 1,
                use_cover_radius: bool,
                max_iter: int | None = None) -> SearchStats:
    nq = queries.shape[0]
    b_cap = frontier
    lane = jnp.arange(nq, dtype=_I32)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (nq,))

    # root distance: computed once, counts once, may itself be a result
    rootv = tree.data[tree.root]
    d_root = one_distance(metric_name, queries,
                          jnp.broadcast_to(rootv, queries.shape))
    res_ids = jnp.full((nq, r_cap + 1), -1, _I32)
    res_cnt = jnp.zeros((nq,), _I32)
    overflow = jnp.zeros((nq,), bool)
    res_ids, res_cnt, overflow = _append_results(
        res_ids, res_cnt, overflow, lane,
        jnp.broadcast_to(tree.root, (nq,))[:, None],
        (d_root <= t)[:, None], r_cap)

    stack_n = jnp.zeros((nq, stack_cap), _I32)
    stack_n = stack_n.at[:, 0].set(tree.root)
    stack_d = jnp.zeros((nq, stack_cap), jnp.float32)
    stack_d = stack_d.at[:, 0].set(d_root)
    sp = jnp.ones((nq,), _I32)
    n_dist = jnp.ones((nq,), _I32)
    stack_ovf = jnp.zeros((nq,), bool)
    if max_iter is None:
        max_iter = tree.data.shape[0] + 8

    def cond(st):
        (_, _, sp, _, _, _, _, _, it) = st
        return jnp.any(sp > 0) & (it < max_iter)

    def body(st):
        (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
         stack_ovf, it) = st
        node, (d_self,), fvalid, sp = _pop_frontier(
            stack_n, (stack_d,), sp, b_cap, stack_cap)  # all (Q, B)

        # ---- frontier gather: every popped node's children, one tile --
        off = tree.child_start[node]
        fcnt = tree.child_count[node]
        fcols = jnp.arange(fan_cap, dtype=_I32)[None, None, :]
        cmask = fvalid[:, :, None] & (fcols < fcnt[:, :, None])  # (Q,B,F)
        cslot = jnp.clip(off[:, :, None] + fcols, 0,
                         jnp.maximum(tree.child_ids.shape[0] - 1, 0))
        cids = tree.child_ids[cslot] if tree.child_ids.shape[0] else \
            jnp.zeros((nq, b_cap, fan_cap), _I32)
        cflat = cids.reshape(nq, b_cap * fan_cap)
        dc = block_distance(
            metric_name, queries, tree.data[cflat],
            pts_norm_sq=tree.norm_sq[cflat]
        ).reshape(nq, b_cap, fan_cap)                    # (Q, B, F)
        dc = jnp.where(cmask, dc, jnp.inf)
        n_dist = n_dist + jnp.sum(cmask, axis=(1, 2)).astype(_I32)

        hits = cmask & (dc <= t[:, None, None])
        res_ids, res_cnt, overflow = _append_results(
            res_ids, res_cnt, overflow, lane, cflat,
            hits.reshape(nq, b_cap * fan_cap), r_cap)

        # winner c* over children ∪ {self}, per popped node
        cmin_idx = jnp.argmin(dc, axis=2)                # (Q, B)
        cmin = jnp.take_along_axis(dc, cmin_idx[:, :, None], 2)[:, :, 0]
        self_wins = d_self < cmin
        dmin = jnp.minimum(cmin, d_self)

        if mechanism == "hilbert":
            # denominator: d(c, c*) — sibling matrix row, or d(c, parent)
            f = fcnt[:, :, None]
            sib_base = tree.sib_off[node][:, :, None]
            sib_idx = sib_base + fcols * f + cmin_idx[:, :, None]
            sib_idx = jnp.clip(sib_idx, 0,
                               jnp.maximum(tree.sib_d.shape[0] - 1, 0))
            d_c_cstar = tree.sib_d[sib_idx] if tree.sib_d.shape[0] else \
                jnp.ones((nq, b_cap, fan_cap), jnp.float32)
            d_den = jnp.where(self_wins[:, :, None], tree.d_parent[cids],
                              d_c_cstar)
            # Never exclude the winner itself (its margin is an exact 0
            # eagerly but FMA-contracted noise over a ~0 denominator in
            # fused loops), and never divide by a near-degenerate
            # bisector (< 1e-6: near-duplicate pivots define no usable
            # hyperplane).
            is_winner = (~self_wins[:, :, None]) & \
                (fcols == cmin_idx[:, :, None])
            margin = jnp.where(
                (d_den > 1e-6) & ~is_winner,
                (dc * dc - dmin[:, :, None] ** 2) /
                (2.0 * jnp.maximum(d_den, 1e-12)),
                -jnp.inf)
        else:
            margin = (dc - dmin[:, :, None]) * 0.5
        excl_c = margin > t[:, None, None]
        if use_cover_radius:
            excl_c = excl_c | (dc > tree.cover_r[cids] + t[:, None, None])
        has_kids = tree.child_count[cids] > 0
        push = cmask & ~excl_c & has_kids

        # ---- multi-push: flip so the top-popped node's children land
        # back on top (depth-first growth); child order kept distal.
        cand_n = jnp.flip(cids, 1).reshape(nq, -1)
        cand_d = jnp.flip(jnp.where(jnp.isfinite(dc), dc, 0.0),
                          1).reshape(nq, -1)
        cand_m = jnp.flip(push, 1).reshape(nq, -1)
        stack_n, (stack_d,), sp, stack_ovf = _multi_push(
            stack_n, (stack_d,), sp, stack_ovf, lane, cand_n, (cand_d,),
            cand_m, stack_cap)

        return (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
                stack_ovf, it + 1)

    init = (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
            stack_ovf, jnp.zeros((), _I32))
    (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow, stack_ovf,
     it) = jax.lax.while_loop(cond, body, init)
    return SearchStats(res_ids[:, :r_cap], res_cnt, n_dist, overflow,
                       stack_ovf, sp > 0, it)


def search_sat(tree: SATree, queries, t, *, metric_name: str,
               mechanism: str = "hilbert", r_cap: int = 128,
               stack_cap: int = 4096, frontier: int = 8,
               use_cover_radius: bool = True,
               max_iter: int | None = None) -> SearchStats:
    """Range search on a DiSAT.  mechanism in {'hyperbolic','hilbert'}.

    ``frontier``: nodes popped per lane per iteration (static); result
    sets and per-query ``n_dist`` are identical for every B >= 1
    (DESIGN.md §3).  ``stack_cap`` (default 4096) bounds in-flight
    breadth; ``stack_overflow`` reports violations.  ``max_iter``: see
    ``search_binary_tree``.
    """
    _check_mechanism(metric_name, mechanism)
    if frontier < 1:
        raise ValueError(f"frontier must be >= 1, got {frontier}")
    fan_cap = max(tree.max_fanout, 1)
    tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return _search_sat(
        tree, jnp.asarray(queries, jnp.float32), t,
        metric_name=metric_name, mechanism=mechanism, r_cap=r_cap,
        stack_cap=stack_cap, fan_cap=fan_cap, frontier=frontier,
        use_cover_radius=use_cover_radius, max_iter=max_iter)


@functools.partial(
    jax.jit, static_argnames=("metric_name", "mechanism", "k", "stack_cap",
                              "fan_cap", "frontier", "use_cover_radius",
                              "max_iter"))
def _knn_sat(tree: SATree, queries: Array, *, metric_name: str,
             mechanism: str, k: int, stack_cap: int, fan_cap: int,
             frontier: int = 1, use_cover_radius: bool,
             max_iter: int | None = None) -> KnnStats:
    nq = queries.shape[0]
    b_cap = frontier
    lane = jnp.arange(nq, dtype=_I32)

    # root distance: computed once, counts once, seeds the best buffer
    rootv = tree.data[tree.root]
    d_root = one_distance(metric_name, queries,
                          jnp.broadcast_to(rootv, queries.shape))
    best_d = jnp.full((nq, k), jnp.inf, jnp.float32)
    best_i = jnp.full((nq, k), _ID_SENT, _I32)
    best_d = best_d.at[:, 0].set(d_root)
    best_i = best_i.at[:, 0].set(tree.root)

    stack_n = jnp.zeros((nq, stack_cap), _I32)
    stack_n = stack_n.at[:, 0].set(tree.root)
    stack_d = jnp.zeros((nq, stack_cap), jnp.float32)
    stack_d = stack_d.at[:, 0].set(d_root)
    stack_m = jnp.full((nq, stack_cap), -jnp.inf, jnp.float32)
    sp = jnp.ones((nq,), _I32)
    n_dist = jnp.ones((nq,), _I32)
    stack_ovf = jnp.zeros((nq,), bool)
    if max_iter is None:
        max_iter = tree.data.shape[0] + 8

    def cond(st):
        (_, _, _, sp, _, _, _, _, it) = st
        return jnp.any(sp > 0) & (it < max_iter)

    def body(st):
        (stack_n, stack_d, stack_m, sp, best_d, best_i, n_dist,
         stack_ovf, it) = st
        node, (d_self, pmargin), fvalid, sp = _pop_frontier(
            stack_n, (stack_d, stack_m), sp, b_cap, stack_cap)

        # best-first re-test against the now-smaller radius
        t_pop = best_d[:, -1]
        fvalid = fvalid & ~(pmargin > t_pop[:, None])
        node = jnp.where(fvalid, node, 0)

        # ---- frontier gather: every popped node's children, one tile --
        off = tree.child_start[node]
        fcnt = tree.child_count[node]
        fcols = jnp.arange(fan_cap, dtype=_I32)[None, None, :]
        cmask = fvalid[:, :, None] & (fcols < fcnt[:, :, None])  # (Q,B,F)
        cslot = jnp.clip(off[:, :, None] + fcols, 0,
                         jnp.maximum(tree.child_ids.shape[0] - 1, 0))
        cids = tree.child_ids[cslot] if tree.child_ids.shape[0] else \
            jnp.zeros((nq, b_cap, fan_cap), _I32)
        cflat = cids.reshape(nq, b_cap * fan_cap)
        dc = block_distance(
            metric_name, queries, tree.data[cflat],
            pts_norm_sq=tree.norm_sq[cflat]
        ).reshape(nq, b_cap, fan_cap)                    # (Q, B, F)
        dc = jnp.where(cmask, dc, jnp.inf)
        n_dist = n_dist + jnp.sum(cmask, axis=(1, 2)).astype(_I32)

        # ---- children -> best buffer; THEN the shrunk radius ----------
        best_d, best_i = _merge_best(
            best_d, best_i, dc.reshape(nq, b_cap * fan_cap), cflat,
            cmask.reshape(nq, b_cap * fan_cap), k)
        tq = best_d[:, -1][:, None, None]                # k-th best NOW

        # winner c* over children ∪ {self}, per popped node
        cmin_idx = jnp.argmin(dc, axis=2)                # (Q, B)
        cmin = jnp.take_along_axis(dc, cmin_idx[:, :, None], 2)[:, :, 0]
        self_wins = d_self < cmin
        dmin = jnp.minimum(cmin, d_self)

        if mechanism == "hilbert":
            # denominator: d(c, c*) — sibling matrix row, or d(c, parent)
            f = fcnt[:, :, None]
            sib_base = tree.sib_off[node][:, :, None]
            sib_idx = sib_base + fcols * f + cmin_idx[:, :, None]
            sib_idx = jnp.clip(sib_idx, 0,
                               jnp.maximum(tree.sib_d.shape[0] - 1, 0))
            d_c_cstar = tree.sib_d[sib_idx] if tree.sib_d.shape[0] else \
                jnp.ones((nq, b_cap, fan_cap), jnp.float32)
            d_den = jnp.where(self_wins[:, :, None], tree.d_parent[cids],
                              d_c_cstar)
            # winner/degenerate-bisector guards: see the identical block
            # in _search_sat for the FMA-contraction rationale
            is_winner = (~self_wins[:, :, None]) & \
                (fcols == cmin_idx[:, :, None])
            margin = jnp.where(
                (d_den > 1e-6) & ~is_winner,
                (dc * dc - dmin[:, :, None] ** 2) /
                (2.0 * jnp.maximum(d_den, 1e-12)),
                -jnp.inf)
        else:
            margin = (dc - dmin[:, :, None]) * 0.5

        lb = margin
        if use_cover_radius:
            lb = jnp.maximum(lb, dc - tree.cover_r[cids])
        has_kids = tree.child_count[cids] > 0
        push = cmask & ~(lb > tq) & has_kids

        # ---- priority order within each node: sort children by
        # DECREASING distance so the nearest lands on the stack top;
        # masked entries (key -inf) sort last and are dropped by push.
        key = jnp.where(push, dc, -jnp.inf)
        order = jnp.argsort(-key, axis=2)
        cids_o = jnp.take_along_axis(cids, order, 2)
        dc_o = jnp.take_along_axis(dc, order, 2)
        lb_o = jnp.take_along_axis(lb, order, 2)
        push_o = jnp.take_along_axis(push, order, 2)

        cand_n = jnp.flip(cids_o, 1).reshape(nq, -1)
        cand_d = jnp.flip(jnp.where(jnp.isfinite(dc_o), dc_o, 0.0),
                          1).reshape(nq, -1)
        cand_l = jnp.flip(jnp.where(jnp.isfinite(lb_o), lb_o, 0.0),
                          1).reshape(nq, -1)
        cand_p = jnp.flip(push_o, 1).reshape(nq, -1)
        stack_n, (stack_d, stack_m), sp, stack_ovf = _multi_push(
            stack_n, (stack_d, stack_m), sp, stack_ovf, lane, cand_n,
            (cand_d, cand_l), cand_p, stack_cap)

        return (stack_n, stack_d, stack_m, sp, best_d, best_i, n_dist,
                stack_ovf, it + 1)

    init = (stack_n, stack_d, stack_m, sp, best_d, best_i, n_dist,
            stack_ovf, jnp.zeros((), _I32))
    (stack_n, stack_d, stack_m, sp, best_d, best_i, n_dist, stack_ovf,
     it) = jax.lax.while_loop(cond, body, init)
    ids = jnp.where(best_i == _ID_SENT, -1, best_i)
    return KnnStats(ids, best_d, n_dist, stack_ovf, sp > 0, it)


def knn_search_sat(tree: SATree, queries, k: int, *, metric_name: str,
                   mechanism: str = "hilbert", stack_cap: int = 4096,
                   frontier: int = 8, use_cover_radius: bool = True,
                   max_iter: int | None = None) -> KnnStats:
    """Exact k-NN on a DiSAT via best-first shrinking-radius traversal.

    Same contract as ``knn_search_binary_tree``: ids/distances ascending
    by (distance, id), identical to ``bruteforce.knn`` including ties;
    ``frontier`` changes ``n_dist`` but never the k-set.
    """
    _check_mechanism(metric_name, mechanism)
    if frontier < 1:
        raise ValueError(f"frontier must be >= 1, got {frontier}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    fan_cap = max(tree.max_fanout, 1)
    tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return _knn_sat(
        tree, jnp.asarray(queries, jnp.float32),
        metric_name=metric_name, mechanism=mechanism, k=k,
        stack_cap=stack_cap, fan_cap=fan_cap, frontier=frontier,
        use_cover_radius=use_cover_radius, max_iter=max_iter)
