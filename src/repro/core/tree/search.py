"""Jittable batched tree traversal with selectable exclusion mechanism.

Both engines run all query lanes in lockstep: each ``lax.while_loop``
iteration pops one node per lane, evaluates the lane's query-to-pivot
distances (the paper's unit of cost — counted exactly), applies the
selected exclusion (hyperbolic / hilbert) plus cover-radius exclusion,
and pushes surviving children.  Lanes with empty stacks idle (masked).

Exact range search: for the same (tree, queries, t) every mechanism must
return the identical result set (paper §6.5); tests assert this.

Static jit arguments: metric name, mechanism, buffer sizes.  The tree is
a dynamic pytree operand, so one compilation serves every tree of the
same shape.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exclusion as excl
from repro.core import metrics as metrics_lib
from repro.core.blockdist import block_distance, one_distance
from repro.core.tree.flat import BinaryHyperplaneTree, SATree

Array = jnp.ndarray

_I32 = jnp.int32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SearchStats:
    """Per-query search outcome.

    res_ids:  (Q, R) original data ids of results (first res_cnt valid,
              capped at R; overflow flags truncation)
    res_cnt:  (Q,) true number of results (may exceed R)
    n_dist:   (Q,) query-to-object distance evaluations (the paper's cost)
    overflow: (Q,) result buffer overflow
    stack_overflow: (Q,) traversal stack overflow (correctness violated if
              set — sized so tests prove it never fires)
    iters:    () loop iterations executed
    """
    res_ids: Any
    res_cnt: Any
    n_dist: Any
    overflow: Any
    stack_overflow: Any
    iters: Any

    def tree_flatten(self):
        return ((self.res_ids, self.res_cnt, self.n_dist, self.overflow,
                 self.stack_overflow, self.iters), None)

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)

    def result_sets(self) -> list[set[int]]:
        """Host-side: per-query sets of result ids (requires no overflow)."""
        ids = np.asarray(self.res_ids)
        cnt = np.asarray(self.res_cnt)
        return [set(ids[i, :min(int(cnt[i]), ids.shape[1])].tolist())
                for i in range(ids.shape[0])]


def _margin(mechanism: str, d1: Array, d2: Array, d12: Array) -> Array:
    if mechanism == "hyperbolic":
        return excl.hyperbolic_margin(d1, d2, d12)
    if mechanism == "hilbert":
        return excl.hilbert_margin(d1, d2, d12)
    raise ValueError(f"unknown mechanism {mechanism!r}")


def _check_mechanism(metric_name: str, mechanism: str) -> None:
    metric = metrics_lib.get(metric_name)
    excl.margin_fn_for(metric, mechanism)  # raises if unsound


def _append_results(res_ids, res_cnt, overflow, lane, ids, hits, r_cap):
    """Append up to W hits per lane into the fixed (Q, R) buffer."""
    pos = res_cnt[:, None] + jnp.cumsum(hits.astype(_I32), axis=1) - 1
    ok = hits & (pos < r_cap)
    wpos = jnp.where(ok, pos, r_cap)              # r_cap column == dropped
    res_ids = res_ids.at[lane[:, None], wpos].set(
        ids.astype(_I32), mode="drop")
    res_cnt = res_cnt + jnp.sum(hits, axis=1).astype(_I32)
    overflow = overflow | (res_cnt > r_cap)
    return res_ids, res_cnt, overflow


# ---------------------------------------------------------------------------
# binary (GHT / MHT)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("metric_name", "mechanism", "r_cap",
                              "stack_cap", "leaf_cap", "use_cover_radius"))
def _search_binary(tree: BinaryHyperplaneTree, queries: Array, t: Array,
                   *, metric_name: str, mechanism: str, r_cap: int,
                   stack_cap: int, leaf_cap: int,
                   use_cover_radius: bool) -> SearchStats:
    nq = queries.shape[0]
    n = tree.data.shape[0]
    lane = jnp.arange(nq, dtype=_I32)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (nq,))

    stack_n = jnp.zeros((nq, stack_cap), _I32)          # root = node 0
    stack_d = jnp.zeros((nq, stack_cap), jnp.float32)
    sp = jnp.ones((nq,), _I32)
    res_ids = jnp.full((nq, r_cap + 1), -1, _I32)       # +1 drop column
    res_cnt = jnp.zeros((nq,), _I32)
    n_dist = jnp.zeros((nq,), _I32)
    overflow = jnp.zeros((nq,), bool)
    stack_ovf = jnp.zeros((nq,), bool)
    max_iter = tree.p1.shape[0] + 8                      # ≤ nodes visited

    def cond(st):
        (_, _, sp, _, _, _, _, _, it) = st
        return jnp.any(sp > 0) & (it < max_iter)

    def body(st):
        (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
         stack_ovf, it) = st
        active = sp > 0
        top = jnp.maximum(sp - 1, 0)
        node = jnp.take_along_axis(stack_n, top[:, None], 1)[:, 0]
        carried = jnp.take_along_axis(stack_d, top[:, None], 1)[:, 0]
        sp = sp - active.astype(_I32)

        left = tree.left[node]
        right = tree.right[node]
        is_int = (left >= 0) & active
        is_leaf = (left < 0) & active

        # ---- internal node ------------------------------------------------
        p1 = tree.p1[node]
        p2 = tree.p2[node]
        d12 = tree.d12[node]
        inh = tree.p1_inherited[node] == 1
        same_pivot = p1 == p2                     # ball-fallback node
        p1v = tree.data[jnp.clip(p1, 0, n - 1)]
        p2v = tree.data[jnp.clip(p2, 0, n - 1)]
        d1f = one_distance(metric_name, queries, p1v)
        d2c = one_distance(metric_name, queries, p2v)
        d1 = jnp.where(inh, carried, d1f)
        d2 = jnp.where(same_pivot, d1, d2c)
        # fresh distances: p1 unless inherited, p2 unless it IS p1
        n_dist = n_dist + jnp.where(
            is_int,
            (1 - inh.astype(_I32)) + (1 - same_pivot.astype(_I32)),
            0)
        hit_p1 = is_int & ~inh & (d1f <= t)
        hit_p2 = is_int & ~same_pivot & (d2 <= t)

        m = _margin(mechanism, d1, d2, d12)
        excl_l = m > t
        excl_r = (-m) > t
        if use_cover_radius:
            excl_l = excl_l | (d1 > tree.cover_r1[node] + t)
            excl_r = excl_r | (d2 > tree.cover_r2[node] + t)
        push_l = is_int & ~excl_l
        push_r = is_int & ~excl_r

        # ---- leaf ----------------------------------------------------------
        start = tree.leaf_start[node]
        cnt = tree.leaf_count[node]
        cols = jnp.arange(leaf_cap, dtype=_I32)[None, :]
        lmask = is_leaf[:, None] & (cols < cnt[:, None])
        bslot = jnp.clip(start[:, None] + cols, 0,
                         jnp.maximum(tree.perm.shape[0] - 1, 0))
        bidx = tree.perm[bslot] if tree.perm.shape[0] else \
            jnp.zeros((nq, leaf_cap), _I32)
        pts = tree.data[bidx]                            # (Q, L, d)
        dl = block_distance(metric_name, queries, pts)
        n_dist = n_dist + jnp.sum(lmask, axis=1).astype(_I32)
        lhit = lmask & (dl <= t[:, None])

        # ---- results ---------------------------------------------------
        ids = jnp.concatenate([p1[:, None], p2[:, None], bidx], axis=1)
        hms = jnp.concatenate(
            [hit_p1[:, None], hit_p2[:, None], lhit], axis=1)
        res_ids, res_cnt, overflow = _append_results(
            res_ids, res_cnt, overflow, lane, ids, hms, r_cap)

        # ---- pushes (right first => left explored first) -----------------
        wr = jnp.where(push_r, sp, stack_cap)
        stack_n = stack_n.at[lane, wr].set(right, mode="drop")
        stack_d = stack_d.at[lane, wr].set(d2, mode="drop")
        sp = sp + push_r.astype(_I32)
        wl = jnp.where(push_l, sp, stack_cap)
        stack_n = stack_n.at[lane, wl].set(left, mode="drop")
        stack_d = stack_d.at[lane, wl].set(d1, mode="drop")
        sp = sp + push_l.astype(_I32)
        stack_ovf = stack_ovf | (sp > stack_cap)

        return (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
                stack_ovf, it + 1)

    init = (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
            stack_ovf, jnp.zeros((), _I32))
    (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow, stack_ovf,
     it) = jax.lax.while_loop(cond, body, init)
    return SearchStats(res_ids[:, :r_cap], res_cnt, n_dist, overflow,
                       stack_ovf, it)


def search_binary_tree(tree: BinaryHyperplaneTree, queries, t, *,
                       metric_name: str, mechanism: str = "hilbert",
                       r_cap: int = 128, stack_cap: int = 128,
                       use_cover_radius: bool = True) -> SearchStats:
    """Range search on a GHT/MHT.  mechanism in {'hyperbolic','hilbert'}."""
    _check_mechanism(metric_name, mechanism)
    leaf_cap = int(np.max(np.asarray(tree.leaf_count))) if \
        tree.leaf_count.shape[0] else 1
    tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return _search_binary(
        tree, jnp.asarray(queries, jnp.float32), t,
        metric_name=metric_name, mechanism=mechanism, r_cap=r_cap,
        stack_cap=stack_cap, leaf_cap=max(leaf_cap, 1),
        use_cover_radius=use_cover_radius)


# ---------------------------------------------------------------------------
# DiSAT
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("metric_name", "mechanism", "r_cap",
                              "stack_cap", "fan_cap", "use_cover_radius"))
def _search_sat(tree: SATree, queries: Array, t: Array, *,
                metric_name: str, mechanism: str, r_cap: int,
                stack_cap: int, fan_cap: int,
                use_cover_radius: bool) -> SearchStats:
    nq = queries.shape[0]
    n = tree.data.shape[0]
    lane = jnp.arange(nq, dtype=_I32)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (nq,))

    # root distance: computed once, counts once, may itself be a result
    rootv = tree.data[tree.root]
    d_root = one_distance(metric_name, queries,
                          jnp.broadcast_to(rootv, queries.shape))
    res_ids = jnp.full((nq, r_cap + 1), -1, _I32)
    res_cnt = jnp.zeros((nq,), _I32)
    overflow = jnp.zeros((nq,), bool)
    res_ids, res_cnt, overflow = _append_results(
        res_ids, res_cnt, overflow, lane,
        jnp.broadcast_to(tree.root, (nq,))[:, None],
        (d_root <= t)[:, None], r_cap)

    stack_n = jnp.zeros((nq, stack_cap), _I32)
    stack_n = stack_n.at[:, 0].set(tree.root)
    stack_d = jnp.zeros((nq, stack_cap), jnp.float32)
    stack_d = stack_d.at[:, 0].set(d_root)
    sp = jnp.ones((nq,), _I32)
    n_dist = jnp.ones((nq,), _I32)
    stack_ovf = jnp.zeros((nq,), bool)
    max_iter = n + 8

    def cond(st):
        (_, _, sp, _, _, _, _, _, it) = st
        return jnp.any(sp > 0) & (it < max_iter)

    def body(st):
        (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
         stack_ovf, it) = st
        active = sp > 0
        top = jnp.maximum(sp - 1, 0)
        node = jnp.take_along_axis(stack_n, top[:, None], 1)[:, 0]
        d_self = jnp.take_along_axis(stack_d, top[:, None], 1)[:, 0]
        sp = sp - active.astype(_I32)

        off = tree.child_start[node]
        fcnt = tree.child_count[node]
        cols = jnp.arange(fan_cap, dtype=_I32)[None, :]
        cmask = active[:, None] & (cols < fcnt[:, None])
        cslot = jnp.clip(off[:, None] + cols, 0,
                         jnp.maximum(tree.child_ids.shape[0] - 1, 0))
        cids = tree.child_ids[cslot] if tree.child_ids.shape[0] else \
            jnp.zeros((nq, fan_cap), _I32)
        pts = tree.data[cids]                          # (Q, F, d)
        dc = block_distance(metric_name, queries, pts)  # (Q, F)
        dc = jnp.where(cmask, dc, jnp.inf)
        n_dist = n_dist + jnp.sum(cmask, axis=1).astype(_I32)

        hits = cmask & (dc <= t[:, None])
        res_ids, res_cnt, overflow = _append_results(
            res_ids, res_cnt, overflow, lane, cids, hits, r_cap)

        # winner c* over children ∪ {self}
        cmin_idx = jnp.argmin(dc, axis=1)              # (Q,)
        cmin = jnp.take_along_axis(dc, cmin_idx[:, None], 1)[:, 0]
        self_wins = d_self < cmin
        dmin = jnp.minimum(cmin, d_self)

        if mechanism == "hilbert":
            # denominator: d(c, c*) — sibling matrix row, or d(c, parent)
            f = fcnt[:, None]
            sib_base = tree.sib_off[node][:, None]
            sib_idx = sib_base + cols * f + cmin_idx[:, None]
            sib_idx = jnp.clip(sib_idx, 0,
                               jnp.maximum(tree.sib_d.shape[0] - 1, 0))
            d_c_cstar = tree.sib_d[sib_idx] if tree.sib_d.shape[0] else \
                jnp.ones((nq, fan_cap), jnp.float32)
            d_den = jnp.where(self_wins[:, None], tree.d_parent[cids],
                              d_c_cstar)
            # Never exclude the winner itself (its margin is an exact 0
            # eagerly but FMA-contracted noise over a ~0 denominator in
            # fused loops), and never divide by a near-degenerate
            # bisector (< 1e-6: near-duplicate pivots define no usable
            # hyperplane).
            is_winner = (~self_wins[:, None]) & (cols == cmin_idx[:, None])
            margin = jnp.where(
                (d_den > 1e-6) & ~is_winner,
                (dc * dc - dmin[:, None] ** 2) /
                (2.0 * jnp.maximum(d_den, 1e-12)),
                -jnp.inf)
        else:
            margin = (dc - dmin[:, None]) * 0.5
        excl_c = margin > t[:, None]
        if use_cover_radius:
            excl_c = excl_c | (dc > tree.cover_r[cids] + t[:, None])
        has_kids = tree.child_count[cids] > 0
        push = cmask & ~excl_c & has_kids

        # batched multi-push
        pos = sp[:, None] + jnp.cumsum(push.astype(_I32), axis=1) - 1
        wpos = jnp.where(push, pos, stack_cap)
        stack_n = stack_n.at[lane[:, None], wpos].set(cids, mode="drop")
        stack_d = stack_d.at[lane[:, None], wpos].set(
            jnp.where(jnp.isfinite(dc), dc, 0.0), mode="drop")
        sp = sp + jnp.sum(push, axis=1).astype(_I32)
        stack_ovf = stack_ovf | (sp > stack_cap)

        return (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
                stack_ovf, it + 1)

    init = (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow,
            stack_ovf, jnp.zeros((), _I32))
    (stack_n, stack_d, sp, res_ids, res_cnt, n_dist, overflow, stack_ovf,
     it) = jax.lax.while_loop(cond, body, init)
    return SearchStats(res_ids[:, :r_cap], res_cnt, n_dist, overflow,
                       stack_ovf, it)


def search_sat(tree: SATree, queries, t, *, metric_name: str,
               mechanism: str = "hilbert", r_cap: int = 128,
               stack_cap: int = 4096,
               use_cover_radius: bool = True) -> SearchStats:
    """Range search on a DiSAT.  mechanism in {'hyperbolic','hilbert'}."""
    _check_mechanism(metric_name, mechanism)
    fan_cap = max(tree.max_fanout, 1)
    tree = jax.tree_util.tree_map(jnp.asarray, tree)
    return _search_sat(
        tree, jnp.asarray(queries, jnp.float32), t,
        metric_name=metric_name, mechanism=mechanism, r_cap=r_cap,
        stack_cap=stack_cap, fan_cap=fan_cap,
        use_cover_radius=use_cover_radius)
