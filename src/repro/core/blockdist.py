"""Per-query-lane block distances for batched tree traversal.

``block_distance(name, q, pts)``: q (Q, d), pts (Q, L, d) -> (Q, L)
distances from each query lane to its own gathered block of L points.
``one_distance(name, q, v)``: q (Q, d), v (Q, d) -> (Q,).

These are the traversal-side mirrors of repro.core.metrics; they avoid
the full (Q, N) pairwise form because each lane gathers different rows.
"""

from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray

_EPS = 1e-12


def _h(x: Array) -> Array:
    safe = jnp.where(x > _EPS, x, 1.0)
    return jnp.where(x > _EPS, -safe * jnp.log2(safe), 0.0)


def block_distance(name: str, q: Array, pts: Array) -> Array:
    """q: (Q, d), pts: (Q, L, d) -> (Q, L)."""
    if name in ("euclidean", "sqeuclidean"):
        qq = jnp.sum(q * q, -1)[:, None]
        pp = jnp.sum(pts * pts, -1)
        qp = jnp.einsum("qd,qld->ql", q, pts)
        d2 = jnp.maximum(qq + pp - 2.0 * qp, 0.0)
        return d2 if name == "sqeuclidean" else jnp.sqrt(d2)
    if name in ("cosine", "angular"):
        qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), _EPS)
        pn = pts / jnp.maximum(
            jnp.linalg.norm(pts, axis=-1, keepdims=True), _EPS)
        sim = jnp.clip(jnp.einsum("qd,qld->ql", qn, pn), -1.0, 1.0)
        if name == "angular":
            return jnp.arccos(sim) / jnp.pi
        return jnp.sqrt(jnp.maximum(1.0 - sim, 0.0))
    if name == "jsd":
        hq = jnp.sum(_h(q), -1)[:, None]
        hp = jnp.sum(_h(pts), -1)
        hqp = jnp.sum(_h(q[:, None, :] + pts), -1)
        return jnp.sqrt(jnp.maximum(1.0 - 0.5 * (hq + hp - hqp), 0.0))
    if name == "triangular":
        diff2 = (q[:, None, :] - pts) ** 2
        den = q[:, None, :] + pts
        terms = jnp.where(den > _EPS, diff2 / jnp.maximum(den, _EPS), 0.0)
        return jnp.sqrt(jnp.maximum(jnp.sum(terms, -1), 0.0))
    if name == "manhattan":
        return jnp.sum(jnp.abs(q[:, None, :] - pts), -1)
    if name == "sqrt_manhattan":
        return jnp.sqrt(jnp.sum(jnp.abs(q[:, None, :] - pts), -1))
    if name == "chebyshev":
        return jnp.max(jnp.abs(q[:, None, :] - pts), -1)
    raise KeyError(name)


def one_distance(name: str, q: Array, v: Array) -> Array:
    """q: (Q, d), v: (Q, d) -> (Q,)."""
    return block_distance(name, q, v[:, None, :])[:, 0]
