"""Distance dispatch layer shared by traversal, brute force and serving.

Two shapes of evaluation, each with a pure-jnp reference path and a
Pallas kernel path (``repro.kernels``):

  ``block_distance(name, q, pts)``: q (Q, d), pts (Q, L, d) -> (Q, L)
  lane-local distances from each query to its own gathered block — the
  frontier-traversal shape, backed by ``kernels.gather_block``.

  ``pairwise_distance(name, q, x)``: q (Q, d), x (N, d) -> (Q, N) dense
  distances — the brute-force / serving shape, backed by
  ``kernels.pairwise`` via ``kernels.ops``.

  ``one_distance(name, q, v)``: q (Q, d), v (Q, d) -> (Q,).

Implementation selection: the ``impl`` argument, else the
``REPRO_GATHER_IMPL`` env var (``jnp`` | ``pallas``), default ``jnp``.
The jnp path is the exactness reference (bit-stable across tile widths,
which the frontier parity tests rely on); the pallas path is the TPU
deployment path (interpret mode on CPU unless REPRO_PALLAS_COMPILED=1).

``pts_norm_sq`` threads the per-tree squared-norm cache (flat.py
``norm_sq``) through to the euclidean/cosine kernels so gathered tiles
never re-reduce the d axis for norms.
"""

from __future__ import annotations

import os

import jax.numpy as jnp

Array = jnp.ndarray

_EPS = 1e-12

DEFAULT_IMPL = os.environ.get("REPRO_GATHER_IMPL", "jnp")


def _h(x: Array) -> Array:
    safe = jnp.where(x > _EPS, x, 1.0)
    return jnp.where(x > _EPS, -safe * jnp.log2(safe), 0.0)


def _block_distance_jnp(name: str, q: Array, pts: Array,
                        pts_norm_sq: Array | None) -> Array:
    if name in ("euclidean", "sqeuclidean"):
        qq = jnp.sum(q * q, -1)[:, None]
        pp = pts_norm_sq if pts_norm_sq is not None else \
            jnp.sum(pts * pts, -1)
        qp = jnp.einsum("qd,qld->ql", q, pts)
        d2 = jnp.maximum(qq + pp - 2.0 * qp, 0.0)
        return d2 if name == "sqeuclidean" else jnp.sqrt(d2)
    if name in ("cosine", "angular"):
        qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), _EPS)
        # sqrt(sum(x*x)) — the cache's expression, NOT linalg.norm (which
        # differs by an ulp), so cached/on-the-fly paths are bit-identical
        pp = pts_norm_sq if pts_norm_sq is not None else \
            jnp.sum(pts * pts, -1)
        pn = pts / jnp.maximum(jnp.sqrt(pp)[..., None], _EPS)
        sim = jnp.clip(jnp.einsum("qd,qld->ql", qn, pn), -1.0, 1.0)
        if name == "angular":
            return jnp.arccos(sim) / jnp.pi
        return jnp.sqrt(jnp.maximum(1.0 - sim, 0.0))
    if name == "jsd":
        hq = jnp.sum(_h(q), -1)[:, None]
        hp = jnp.sum(_h(pts), -1)
        hqp = jnp.sum(_h(q[:, None, :] + pts), -1)
        return jnp.sqrt(jnp.maximum(1.0 - 0.5 * (hq + hp - hqp), 0.0))
    if name == "triangular":
        diff2 = (q[:, None, :] - pts) ** 2
        den = q[:, None, :] + pts
        terms = jnp.where(den > _EPS, diff2 / jnp.maximum(den, _EPS), 0.0)
        return jnp.sqrt(jnp.maximum(jnp.sum(terms, -1), 0.0))
    if name == "manhattan":
        return jnp.sum(jnp.abs(q[:, None, :] - pts), -1)
    if name == "sqrt_manhattan":
        return jnp.sqrt(jnp.sum(jnp.abs(q[:, None, :] - pts), -1))
    if name == "chebyshev":
        return jnp.max(jnp.abs(q[:, None, :] - pts), -1)
    raise KeyError(name)


def block_distance(name: str, q: Array, pts: Array, *,
                   pts_norm_sq: Array | None = None,
                   impl: str | None = None) -> Array:
    """q: (Q, d), pts: (Q, L, d) -> (Q, L)."""
    impl = DEFAULT_IMPL if impl is None else impl
    if impl == "pallas":
        from repro.kernels import gather_block, ops
        kind = "cosine_prenorm" if name == "cosine" else name
        if kind in gather_block.SUPPORTED:
            if name == "cosine":
                q = q / jnp.maximum(
                    jnp.linalg.norm(q, axis=-1, keepdims=True), _EPS)
            # ops.INTERPRET is THE CPU/TPU switch for every kernel
            # family; read at trace time so both halves of the dispatch
            # layer run in the same mode
            return gather_block.gather_block_pallas(
                q, pts, pts_norm_sq, kind, interpret=ops.INTERPRET)
    elif impl != "jnp":
        raise ValueError(f"unknown block-distance impl {impl!r}")
    return _block_distance_jnp(name, q, pts, pts_norm_sq)


def one_distance(name: str, q: Array, v: Array, *,
                 impl: str | None = None) -> Array:
    """q: (Q, d), v: (Q, d) -> (Q,)."""
    impl = DEFAULT_IMPL if impl is None else impl
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"unknown block-distance impl {impl!r}")
    # lane tiles of width 1 always take the jnp path: a Pallas launch
    # per single column would be pure overhead.
    return _block_distance_jnp(name, q, v[:, None, :], None)[:, 0]


def pairwise_distance(name: str, q: Array, x: Array, *,
                      impl: str | None = None) -> Array:
    """q: (Q, d), x: (N, d) -> (Q, N) dense pairwise distances."""
    impl = DEFAULT_IMPL if impl is None else impl
    if impl == "pallas":
        from repro.kernels import ops
        if name in ops.SUPPORTED:
            return ops.pairwise_distance(q, x, name)
    elif impl != "jnp":
        raise ValueError(f"unknown pairwise impl {impl!r}")
    from repro.core import metrics as metrics_lib
    return metrics_lib.get(name).pairwise(q, x)
