"""Finite isometric embedding verifiers (paper §2.2, §5, Lemma 5).

Production role: before enabling Hilbert Exclusion for a *user-supplied*
metric, the framework can empirically screen random quadruples with the
Lemma-5 test.  A single failing quadruple proves the space is NOT
4-embeddable (and Hilbert Exclusion would be unsound); passing many
quadruples is strong statistical evidence (soundness for our built-in
metrics is analytic, per the paper).

Lemma 5 (Blumenthal): (X,d) is isometrically 4-embeddable in l2^3 iff for
every 4 points and all c with sum(c)=0:  sum_ij c_i c_j d(x_i,x_j)^2 <= 0,
i.e. the squared-distance matrix D2 is conditionally negative semidefinite
(CNSD) on the hyperplane sum(c)=0.

Equivalent operational test: let P project onto {c : sum c = 0}; then
D2 is CNSD iff the symmetric matrix -P D2 P is PSD. For 4x4 this is three
eigenvalues >= 0 (one is always ~0 along the excluded direction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def cnsd_defect(d2: Array, *, tol_scale: float = 1e-5) -> Array:
    """Largest violation of conditional negative semidefiniteness.

    d2: (..., k, k) matrix of SQUARED distances among k points.
    Returns (...,) defect >= 0; a value ~0 (within tol) means the quadruple
    passes the Lemma-5 test.  Defect = max eigenvalue of P(-D2)P negated...

    Concretely we compute  lambda_max( P @ D2 @ P )  where
    P = I - 11^T/k; CNSD  <=>  that value <= 0 (up to fp noise).
    """
    k = d2.shape[-1]
    eye = jnp.eye(k, dtype=d2.dtype)
    p = eye - jnp.full((k, k), 1.0 / k, dtype=d2.dtype)
    m = p @ d2 @ p
    m = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    eig = jnp.linalg.eigvalsh(m)
    scale = jnp.maximum(jnp.max(jnp.abs(d2), axis=(-2, -1)), 1.0)
    del tol_scale  # caller applies tolerance; we return the raw defect
    return jnp.max(eig, axis=-1) / scale


def is_four_embeddable_quadruple(d2: Array, tol: float = 1e-5) -> Array:
    """Boolean Lemma-5 verdict for (..., 4, 4) squared-distance matrices."""
    return cnsd_defect(d2) <= tol


def quadruple_distance_matrix(metric, pts: Array) -> Array:
    """pts: (..., 4, d) -> (..., 4, 4) squared distances under ``metric``."""
    def one(p):
        d = metric.pairwise(p, p)
        return d * d
    flat = pts.reshape((-1,) + pts.shape[-2:])
    out = jax.vmap(one)(flat)
    return out.reshape(pts.shape[:-2] + (4, 4))


def screen_metric(metric, sample: Array, n_quadruples: int, key,
                  tol: float = 1e-5) -> tuple[Array, Array]:
    """Empirical four-point screen: draw random quadruples from ``sample``
    (n, d) and test each.  Returns (fraction_passing, worst_defect).

    fraction < 1 ==> metric certainly lacks the property (Hilbert Exclusion
    unsound).  fraction == 1 is evidence (not proof) it holds.
    """
    n = sample.shape[0]
    idx = jax.random.randint(key, (n_quadruples, 4), 0, n)
    pts = sample[idx]                       # (Q, 4, d)
    d2 = quadruple_distance_matrix(metric, pts)
    defect = cnsd_defect(d2)
    ok = defect <= tol
    return jnp.mean(ok.astype(jnp.float32)), jnp.max(defect)


def embed_quadruple_l2(d2: Array) -> Array:
    """Constructive 4-embedding: return (4, 3) coordinates whose pairwise
    squared distances reproduce ``d2`` (4x4), when it is CNSD.

    Classical MDS: G = -1/2 P D2 P is PSD Gram; factor via eigh. Raises no
    error on non-embeddable input — caller should check cnsd_defect first
    (negative eigenvalues are clipped, distorting distances).
    """
    k = d2.shape[-1]
    p = jnp.eye(k, dtype=d2.dtype) - 1.0 / k
    g = -0.5 * (p @ d2 @ p)
    g = 0.5 * (g + g.T)
    w, v = jnp.linalg.eigh(g)
    w = jnp.maximum(w, 0.0)
    coords = v * jnp.sqrt(w)[None, :]
    return coords[:, -3:]                   # top-3 eigendirections suffice
