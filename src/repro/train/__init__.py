from repro.train.loop import TrainLoopConfig, train_loop  # noqa: F401
from repro.train import fault_tolerance  # noqa: F401
