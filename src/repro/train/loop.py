"""Generic training loop: jit'd step with donation, microbatch gradient
accumulation (the cross-pod overlap window), async checkpointing,
preemption-safe exit, straggler accounting.

The loop is model-agnostic: it takes a ``loss_fn(params, batch)`` and
wires optimizer/state plumbing around it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.io import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.train.fault_tolerance import PreemptionGuard, StragglerDetector


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    microbatches: int = 1          # gradient accumulation
    log_every: int = 10
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                    microbatches: int = 1, donate: bool = True):
    """Builds the jit'd (params, opt_state, batch) -> (params, opt_state,
    metrics) step.  With microbatches > 1 the batch's leading axis is
    split and gradients accumulate in f32 before one optimizer update —
    the standard trick that both bounds activation memory and gives the
    cross-pod all-reduce a full microbatch of compute to overlap with.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            split = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches, -1) + x.shape[1:]),
                batch)

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros(()), g0), split)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
        params, opt_state, metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    donate_args = (0, 1) if donate else ()
    return jax.jit(step, donate_argnums=donate_args)


def train_loop(loss_fn: Callable, params, make_batch: Callable[[int], Any],
               cfg: TrainLoopConfig, *, opt_state=None, start_step: int = 0,
               resume: bool = True):
    """Runs training; returns (params, opt_state, history).

    Restart contract: with ``resume=True`` and a ckpt_dir containing
    step_N, training resumes at N+1 with identical state and (seed,
    step)-keyed batches — the fault-tolerance test kills the loop
    mid-run and asserts bitwise state continuity.
    """
    step_fn = make_train_step(loss_fn, cfg.optimizer, cfg.microbatches)
    ckpt = AsyncCheckpointer(cfg.ckpt_dir) if cfg.ckpt_dir else None
    if opt_state is None:
        opt_state = adamw_init(params)

    if resume and cfg.ckpt_dir and latest_step(cfg.ckpt_dir) is not None:
        state = {"params": params, "opt": opt_state}
        state, saved_step, extra = restore_checkpoint(cfg.ckpt_dir, state)
        params, opt_state = state["params"], state["opt"]
        start_step = saved_step + 1

    guard = PreemptionGuard()
    straggler = StragglerDetector()
    history = []
    step = start_step
    try:
        while step < cfg.total_steps:
            t0 = time.monotonic()
            batch = make_batch(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            straggler.record(dt)
            if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
                history.append({"step": step,
                                "loss": float(metrics["loss"]),
                                "grad_norm": float(metrics["grad_norm"]),
                                "sec": dt})
            want_ckpt = ckpt and (step % cfg.ckpt_every == 0
                                  or step == cfg.total_steps - 1)
            if want_ckpt or (ckpt and guard.preempted):
                ckpt.save(step, {"params": params, "opt": opt_state},
                          extra={"straggler_flags": straggler.flagged})
            if guard.preempted:
                break
            step += 1
    finally:
        if ckpt:
            ckpt.wait()
        guard.restore()
    return params, opt_state, history
