"""Fault-tolerance runtime: preemption capture, straggler detection,
elastic restart protocol (DESIGN.md §5).

At 1000+ nodes the failure model is: (a) SIGTERM preemptions, (b) silent
node loss (missed heartbeat), (c) stragglers (healthy but slow hosts).
This module provides the pieces the launcher composes:

  * PreemptionGuard — SIGTERM/SIGINT turn into a "save and exit" flag
    checked once per step (never mid-collective).
  * StragglerDetector — per-step duration ring buffer; a host whose
    median step exceeds k * fleet MAD is flagged for eviction.  In the
    single-process container the "fleet" is simulated per-step timings;
    on a real cluster each host reports via the coordination service.
  * elastic protocol (documented + simulated in tests): on membership
    change, surviving hosts re-run make_mesh over the new device set,
    restore the latest checkpoint with the NEW shardings (checkpoint/io
    saves logical full arrays precisely so any mesh can load them), and
    resume from the recorded step — data order is reproducible because
    batches are keyed by (seed, step), not by wall clock.
"""

from __future__ import annotations

import signal
import time
from collections import deque
from typing import Optional


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a cooperative stop flag."""

    def __init__(self, install: bool = True):
        self.preempted = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:          # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.preempted = True

    def restore(self):
        for sig, h in self._prev.items():
            signal.signal(sig, h)


class StragglerDetector:
    """Flags steps (hosts) whose duration exceeds median + k * MAD."""

    def __init__(self, window: int = 50, k: float = 6.0):
        self.window = window
        self.k = k
        self._durs: deque[float] = deque(maxlen=window)
        self.flagged = 0

    def record(self, duration_s: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        durs = sorted(self._durs)
        is_straggler = False
        if len(durs) >= 10:
            med = durs[len(durs) // 2]
            mad = sorted(abs(d - med) for d in durs)[len(durs) // 2]
            if duration_s > med + self.k * max(mad, 1e-4):
                is_straggler = True
                self.flagged += 1
        self._durs.append(duration_s)
        return is_straggler


class Heartbeat:
    """Liveness: a host that hasn't beaten within ``timeout_s`` is
    declared lost and the elastic restart protocol begins."""

    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.last_beat: Optional[float] = None

    def beat(self):
        self.last_beat = time.monotonic()

    def alive(self) -> bool:
        return (self.last_beat is not None
                and time.monotonic() - self.last_beat < self.timeout_s)
