from repro.data import synthetic, sampler, pipeline  # noqa: F401
