"""GNN neighbour sampler for the `minibatch_lg` cell (fanout 15-10).

A REAL sampler (not a stub): builds a CSR adjacency once, then per batch
draws seed nodes and samples up to fanout neighbours per hop, emitting
fixed-shape padded blocks (required for jit):

  nodes   : (n_max,) unique node ids (padded with -1 -> feature row 0)
  src/dst : (e_max,) LOCAL indices into nodes
  edge_mask, label_mask, labels
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray
    indices: np.ndarray
    n_nodes: int

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int
                   ) -> "CSRGraph":
        order = np.argsort(dst, kind="stable")
        s, d = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, d + 1, 1)
        indptr = np.cumsum(indptr)
        return CSRGraph(indptr=indptr, indices=s.astype(np.int64),
                        n_nodes=n_nodes)


def sample_block(graph: CSRGraph, features: np.ndarray, labels: np.ndarray,
                 seeds: np.ndarray, fanouts: tuple[int, ...], *,
                 rng: np.random.Generator) -> dict:
    """Layer-wise neighbour sampling (GraphSAGE style)."""
    frontier = seeds.astype(np.int64)
    all_src, all_dst = [], []
    nodes = list(frontier)
    node_pos = {int(v): i for i, v in enumerate(frontier)}

    for fan in fanouts:
        nxt = []
        for v in frontier:
            lo, hi = graph.indptr[v], graph.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(fan, deg)
            sel = graph.indices[lo + rng.choice(deg, take, replace=False)]
            for u in sel:
                u = int(u)
                if u not in node_pos:
                    node_pos[u] = len(nodes)
                    nodes.append(u)
                all_src.append(node_pos[u])
                all_dst.append(node_pos[int(v)])
            nxt.extend(int(u) for u in sel)
        frontier = np.asarray(nxt, np.int64) if nxt else frontier[:0]

    n_max = len(seeds)
    for fan in fanouts:
        n_max = n_max * (fan + 1)
    e_max = max(len(all_src), 1)
    # round up to stable shapes across batches
    n_pad = int(2 ** np.ceil(np.log2(max(len(nodes), 2))))
    e_pad = int(2 ** np.ceil(np.log2(max(e_max, 2))))

    node_ids = np.full(n_pad, -1, np.int64)
    node_ids[:len(nodes)] = nodes
    feat = np.zeros((n_pad, features.shape[1]), np.float32)
    feat[:len(nodes)] = features[nodes]
    lab = np.zeros(n_pad, np.int32)
    lab[:len(nodes)] = labels[nodes]
    label_mask = np.zeros(n_pad, np.float32)
    label_mask[:len(seeds)] = 1.0          # loss only on the seed nodes
    src = np.zeros(e_pad, np.int32)
    dst = np.zeros(e_pad, np.int32)
    src[:len(all_src)] = all_src
    dst[:len(all_dst)] = all_dst
    edge_mask = np.zeros(e_pad, bool)
    edge_mask[:len(all_src)] = True
    return {"x": feat, "src": src, "dst": dst, "edge_mask": edge_mask,
            "labels": lab, "label_mask": label_mask,
            "node_ids": node_ids}
