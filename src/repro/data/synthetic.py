"""Synthetic corpora for every arch family (offline container: no
downloads).  Deterministic per (seed, step) => restart-reproducible, which
the fault-tolerance tests rely on.
"""

from __future__ import annotations

import numpy as np


def token_batch(seed: int, step: int, batch: int, seq: int,
                vocab: int) -> dict:
    """LM batch: zipf-ish token stream + next-token targets."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # zipf via inverse-cdf over ranked ids (heavier head than uniform)
    u = rng.random((batch, seq + 1))
    toks = np.minimum((vocab * u ** 2.2).astype(np.int64), vocab - 1)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32)}


def click_batch(seed: int, step: int, batch: int, n_dense: int,
                vocab_sizes, *, seq_len: int = 0) -> dict:
    """Criteo-like CTR batch; optional behaviour sequence for BST."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 7]))
    out = {
        "dense": rng.normal(size=(batch, n_dense)).astype(np.float32),
        "sparse_ids": np.stack(
            [rng.integers(0, v, batch) for v in vocab_sizes],
            axis=1).astype(np.int32),
        "labels": (rng.random(batch) < 0.25).astype(np.float32),
    }
    if seq_len:
        out["hist_ids"] = rng.integers(
            0, vocab_sizes[0], (batch, seq_len)).astype(np.int32)
        out["target_id"] = rng.integers(0, vocab_sizes[0], batch) \
            .astype(np.int32)
    return out


def retrieval_batch(seed: int, step: int, batch: int, n_user_feats: int,
                    n_item_feats: int, user_vocab: int,
                    item_vocab: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 11]))
    return {
        "user_ids": rng.integers(0, user_vocab,
                                 (batch, n_user_feats)).astype(np.int32),
        "item_ids": rng.integers(0, item_vocab,
                                 (batch, n_item_feats)).astype(np.int32),
    }


def random_graph(seed: int, n_nodes: int, n_edges: int, d_feat: int,
                 n_classes: int = 40, *, power_law: bool = True) -> dict:
    """Undirected-ish edge list with power-law-ish degree distribution
    (the regime GNN samplers face on ogbn-style graphs)."""
    rng = np.random.default_rng(seed)
    if power_law:
        w = 1.0 / np.arange(1, n_nodes + 1) ** 0.5
        p = w / w.sum()
        src = rng.choice(n_nodes, n_edges, p=p).astype(np.int32)
    else:
        src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    return {
        "x": rng.normal(size=(n_nodes, d_feat)).astype(np.float32),
        "src": src, "dst": dst,
        "labels": rng.integers(0, n_classes, n_nodes).astype(np.int32),
    }


def molecule_batch(seed: int, step: int, batch: int, n_nodes: int,
                   n_edges: int, d_feat: int, n_classes: int = 2) -> dict:
    """`molecule` cell: `batch` small graphs padded into one block."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 13]))
    total_n = batch * n_nodes
    x = rng.normal(size=(total_n, d_feat)).astype(np.float32)
    src = np.concatenate([
        rng.integers(0, n_nodes, n_edges) + g * n_nodes
        for g in range(batch)]).astype(np.int32)
    dst = np.concatenate([
        rng.integers(0, n_nodes, n_edges) + g * n_nodes
        for g in range(batch)]).astype(np.int32)
    graph_ids = np.repeat(np.arange(batch), n_nodes).astype(np.int32)
    labels = rng.integers(0, n_classes, batch).astype(np.int32)
    return {"x": x, "src": src, "dst": dst, "graph_ids": graph_ids,
            "labels": labels}


def metric_space(seed: int, n: int, dim: int, *, simplex: bool = False,
                 clustered: int = 0) -> np.ndarray:
    """Paper §6.1 spaces: uniform unit hypercube; ``clustered`` > 0 gives
    a Gaussian-mixture stand-in for the SISAP real-data regime."""
    rng = np.random.default_rng(seed)
    if clustered:
        # multi-scale mixture: per-cluster sigma log-uniform in
        # [0.02, 0.25] — real feature datasets (SISAP colors/nasa) have
        # structure at several scales; single-scale blobs make hyperplane
        # exclusion artificially useless
        centers = rng.random((clustered, dim))
        sigma = np.exp(rng.uniform(np.log(0.02), np.log(0.25), clustered))
        which = rng.integers(0, clustered, n)
        pts = centers[which] + sigma[which, None] * rng.normal(
            size=(n, dim))
        pts = np.abs(pts)
    else:
        pts = rng.random((n, dim))
    pts = pts.astype(np.float32)
    if simplex:
        pts = pts / np.maximum(pts.sum(-1, keepdims=True), 1e-9)
    return pts
