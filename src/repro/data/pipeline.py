"""Host input pipeline: background prefetch with a bounded queue.

Keeps the accelerator step from ever waiting on host batch synthesis
(straggler mitigation lever #1 — DESIGN.md §5): depth-2+ prefetch decouples
host jitter from device step time.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator


class Prefetcher:
    """Wraps a step->batch function in a producer thread."""

    def __init__(self, make_batch: Callable[[int], dict], start_step: int,
                 depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
