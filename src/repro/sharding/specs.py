"""Logical sharding rules -> PartitionSpecs per model family.

Axis conventions (DESIGN.md §5):
  "data"  : FSDP/ZeRO param+opt sharding, batch data-parallel axis
  "model" : tensor parallel (heads / ffn hidden / experts / vocab)
  "pod"   : pure data parallel across pods (multi-pod mesh only);
            batch shards over ("pod", "data"), params replicate over pod
            so the gradient all-reduce is the only cross-pod collective.

All functions return pytrees of jax.sharding.PartitionSpec matching the
corresponding param/batch pytrees.  ``batch_axes(mesh)`` resolves to
("pod", "data") when the mesh has a pod axis, else "data".
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Array = Any


def batch_axes(mesh) -> tuple[str, ...] | str:
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


# ---------------------------------------------------------------------------
# mesh context: lets model code state logical constraints without holding
# a mesh reference.  Outside any context, constrain() is the identity, so
# single-device tests/smokes are untouched.
# ---------------------------------------------------------------------------

_CTX: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "repro_mesh_ctx", default=None)

BATCH = "__batch__"      # placeholder resolved to ("pod","data") / "data"


@contextlib.contextmanager
def mesh_context(mesh, **extra):
    tok = _CTX.set({"mesh": mesh, "batch": batch_axes(mesh), **extra})
    try:
        yield
    finally:
        _CTX.reset(tok)


def ctx_flag(name: str, default=None):
    ctx = _CTX.get()
    return default if ctx is None else ctx.get(name, default)


def constrain(x, *spec_parts):
    """with_sharding_constraint with BATCH placeholder resolution; no-op
    outside a mesh_context."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    parts = tuple(ctx["batch"] if p == BATCH else p for p in spec_parts)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx["mesh"], P(*parts)))


def _map_with_path(tree, fn):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(jax.tree_util.keystr(path), leaf), tree)


# ---------------------------------------------------------------------------
# transformer
# ---------------------------------------------------------------------------

def transformer_param_specs(params, cfg, mesh, *, ep: bool | None = None):
    """FSDP over "data" + TP over "model".

    GQA: kv projections shard head_dim-packed output columns only when
    n_kv_heads divides the model axis; here n_kv (4/8) < model(16), so
    w_k/w_v shard the INPUT (d_model) dim on "model" instead — the
    output stays replicated model-wise (cheap: kv proj is small) and the
    QK^T contraction stays local.  MoE experts shard "model" when
    divisible (EP), else the per-expert ffn dim (TP).
    """
    msize = mesh.shape["model"]
    if cfg.moe is not None and ep is None:
        ep = cfg.moe.n_experts % msize == 0

    def rule(path: str, leaf):
        if "embed" in path:
            return P("model", None)            # vocab rows over model
        if "unembed" in path:
            return P(None, "model")            # logits cols over model
        if "final_norm" in path or "norm" in path:
            return P()
        if "attn" in path:
            if "w_q" in path:
                return P(None, "data", "model")
            if "w_o" in path:
                return P(None, "model", "data")
            # w_k / w_v: (L, D, Hkv*dh) — kv_heads (8/4) < model axis
            # (16), so replicate model-wise (small) and FSDP over data;
            # sharding D on "model" instead turns every K/V projection
            # into an activation-sized partial-sum all-reduce.
            return P(None, "data", None)
        if "moe" in path:
            if "router" in path:
                return P(None, "data", None)
            if ep:
                # (L, E, D, F) / (L, E, F, D): experts over model
                return P(None, "model", "data", None)
            return (P(None, None, "data", "model")
                    if ("w_up" in path or "w_gate" in path)
                    else P(None, None, "model", "data"))
        if "mlp" in path:
            if "w_down" in path:
                return P(None, "model", "data")
            return P(None, "data", "model")    # w_up / w_gate
        return P()

    return _map_with_path(params, rule)


def transformer_batch_specs(mesh):
    b = batch_axes(mesh)
    return {"tokens": P(b, None), "targets": P(b, None)}


def transformer_cache_specs(mesh, *, long_context: bool):
    """decode_32k: batch-sharded cache; long_500k: sequence-sharded cache
    (flash-decoding over chips — DESIGN.md §4)."""
    b = batch_axes(mesh)
    if long_context:
        kv = P(None, None, b, None, None)       # (L, B, S, Hkv, dh)
    else:
        kv = P(None, b, None, None, None)
    return {"k": kv, "v": kv, "len": P()}


# ---------------------------------------------------------------------------
# gnn
# ---------------------------------------------------------------------------

def pna_param_specs(params, mesh):
    def rule(path: str, leaf):
        if leaf.ndim == 2:
            return P(None, "model") if leaf.shape[-1] % mesh.shape["model"] \
                == 0 else P()
        return P()
    return _map_with_path(params, rule)


def pna_batch_specs(mesh):
    b = batch_axes(mesh)
    return {"x": P(), "src": P(b), "dst": P(b),
            "labels": P(), "edge_mask": P(b), "label_mask": P()}


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------

def recsys_param_specs(params, mesh):
    """Embedding tables: rows sharded over ("model",) (the classic DLRM
    model-parallel layout — tables are the memory, MLPs are small and
    FSDP-shard over data where divisible)."""
    def rule(path: str, leaf):
        if "table" in path:
            return P("model", None)
        if leaf.ndim == 2 and leaf.shape[0] % mesh.shape["model"] == 0 \
                and leaf.shape[0] >= 256:
            return P("model", None)
        return P()
    return _map_with_path(params, rule)


def recsys_batch_specs(mesh):
    b = batch_axes(mesh)
    return {"dense": P(b, None), "sparse_ids": P(b, None),
            "labels": P(b), "hist_ids": P(b, None), "target_id": P(b),
            "user_ids": P(b, None), "item_ids": P(b, None)}


def named_sharding_tree(mesh, spec_tree):
    from jax.sharding import NamedSharding
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
