"""RecSys model zoo: DLRM, DCN-v2, BST, two-tower retrieval.

The embedding LOOKUP is the hot path; JAX has no nn.EmbeddingBag, so
``embedding_bag`` here (jnp.take + segment-style reduction) IS the
substrate (kernel_taxonomy §RecSys).  Tables are a single fused row
space (per-feature offsets) so one gather serves all 26 features and
sharding the row dim distributes the whole embedding memory.

Serving paths:
  serve_p99 / serve_bulk : plain forward at batch 512 / 262144
  retrieval_cand         : 1 query vs 10^6 candidates — batched dot
                           (two-tower) or batched forward (CTR models);
                           optionally backed by repro.core metric search
                           over d_cos (the paper's technique).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.layers import truncated_normal

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# embedding substrate
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EmbeddingSpec:
    vocab_sizes: tuple[int, ...]     # rows per sparse feature
    dim: int
    row_pad: int = 512               # pad total rows so the fused table
    #                                  shards over any <=512-chip mesh

    @property
    def total_rows(self) -> int:
        n = sum(self.vocab_sizes)
        return ((n + self.row_pad - 1) // self.row_pad) * self.row_pad

    @property
    def offsets(self) -> tuple[int, ...]:
        out, acc = [], 0
        for v in self.vocab_sizes:
            out.append(acc)
            acc += v
        return tuple(out)


def init_embedding(key, spec: EmbeddingSpec, dtype=jnp.float32) -> Array:
    return truncated_normal(key, (spec.total_rows, spec.dim),
                            spec.dim ** -0.5, dtype)


def embedding_lookup(table: Array, spec: EmbeddingSpec,
                     sparse_ids: Array, feat_offset: int = 0) -> Array:
    """sparse_ids: (B, F) per-feature local ids -> (B, F, dim).

    One fused gather over the offset row space (= EmbeddingBag with one
    id per bag; multi-id bags below).  ``feat_offset`` selects which
    slice of the spec's features these columns correspond to (e.g. the
    item-tower features of a shared two-tower table)."""
    f = sparse_ids.shape[1]
    offsets = jnp.asarray(spec.offsets[feat_offset:feat_offset + f],
                          jnp.int32)
    rows = sparse_ids + offsets[None, :]
    return jnp.take(table, rows, axis=0)


def embedding_bag(table: Array, ids: Array, bag_ids: Array, n_bags: int,
                  *, combiner: str = "sum") -> Array:
    """EmbeddingBag: ids (K,) row ids, bag_ids (K,) target bag -> (n_bags,
    dim) via gather + segment_sum (mean optional)."""
    gathered = jnp.take(table, ids, axis=0)
    summed = jax.ops.segment_sum(gathered, bag_ids, n_bags)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, table.dtype), bag_ids,
                                  n_bags)
        summed = summed / jnp.maximum(cnt, 1.0)[:, None]
    return summed


def _mlp_init(key, sizes: Sequence[int], dtype) -> list[dict]:
    ks = jax.random.split(key, len(sizes) - 1)
    return [{"w": truncated_normal(ks[i], (sizes[i], sizes[i + 1]),
                                   sizes[i] ** -0.5, dtype),
             "b": jnp.zeros((sizes[i + 1],), dtype)}
            for i in range(len(sizes) - 1)]


def _mlp_apply(mlp: list[dict], x: Array, final_act: bool = False) -> Array:
    for i, lp in enumerate(mlp):
        x = x @ lp["w"] + lp["b"]
        if i < len(mlp) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# DLRM (arXiv:1906.00091, MLPerf config)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_dense: int = 13
    embed: EmbeddingSpec = EmbeddingSpec((), 128)
    bot_mlp: tuple[int, ...] = (13, 512, 256, 128)
    top_mlp: tuple[int, ...] = (1024, 1024, 512, 256, 1)
    dtype: object = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.embed.vocab_sizes)


def dlrm_init(key, cfg: DLRMConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    n_f = cfg.n_sparse + 1
    n_inter = n_f * (n_f - 1) // 2
    top_in = cfg.embed.dim + n_inter
    return {
        "table": init_embedding(k1, cfg.embed, cfg.dtype),
        "bot": _mlp_init(k2, cfg.bot_mlp, cfg.dtype),
        "top": _mlp_init(k3, (top_in,) + cfg.top_mlp[1:], cfg.dtype),
    }


def dlrm_forward(params: dict, cfg: DLRMConfig, dense: Array,
                 sparse_ids: Array) -> Array:
    """dense: (B, 13) f32; sparse_ids: (B, 26) -> (B,) logits."""
    b = dense.shape[0]
    z = _mlp_apply(params["bot"], dense.astype(cfg.dtype), final_act=True)
    emb = embedding_lookup(params["table"], cfg.embed, sparse_ids)
    feats = jnp.concatenate([z[:, None, :], emb], axis=1)   # (B, 27, dim)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(feats.shape[1], k=1)
    flat = inter[:, iu, ju]                                  # (B, 351)
    top_in = jnp.concatenate([z, flat], axis=-1)
    return _mlp_apply(params["top"], top_in)[:, 0]


# ---------------------------------------------------------------------------
# DCN-v2 (arXiv:2008.13535)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DCNConfig:
    name: str
    n_dense: int = 13
    embed: EmbeddingSpec = EmbeddingSpec((), 16)
    n_cross: int = 3
    mlp: tuple[int, ...] = (1024, 1024, 512)
    dtype: object = jnp.float32


def dcn_init(key, cfg: DCNConfig) -> dict:
    d0 = cfg.n_dense + len(cfg.embed.vocab_sizes) * cfg.embed.dim
    ks = jax.random.split(key, 3 + cfg.n_cross)
    p = {
        "table": init_embedding(ks[0], cfg.embed, cfg.dtype),
        "cross": [{"w": truncated_normal(ks[1 + i], (d0, d0), d0 ** -0.5,
                                         cfg.dtype),
                   "b": jnp.zeros((d0,), cfg.dtype)}
                  for i in range(cfg.n_cross)],
        "mlp": _mlp_init(ks[-2], (d0,) + cfg.mlp, cfg.dtype),
        "head": truncated_normal(ks[-1], (cfg.mlp[-1], 1),
                                 cfg.mlp[-1] ** -0.5, cfg.dtype),
    }
    return p


def dcn_forward(params: dict, cfg: DCNConfig, dense: Array,
                sparse_ids: Array) -> Array:
    emb = embedding_lookup(params["table"], cfg.embed, sparse_ids)
    x0 = jnp.concatenate(
        [dense.astype(cfg.dtype), emb.reshape(emb.shape[0], -1)], axis=-1)
    x = x0
    for lp in params["cross"]:
        x = x0 * (x @ lp["w"] + lp["b"]) + x       # DCN-v2 cross
    h = _mlp_apply(params["mlp"], x, final_act=True)
    return (h @ params["head"])[:, 0]


# ---------------------------------------------------------------------------
# BST (arXiv:1905.06874)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str
    embed: EmbeddingSpec = EmbeddingSpec((), 32)   # item vocab in [0]
    seq_len: int = 20
    n_heads: int = 8
    n_blocks: int = 1
    mlp: tuple[int, ...] = (1024, 512, 256)
    dtype: object = jnp.float32


def bst_init(key, cfg: BSTConfig) -> dict:
    d = cfg.embed.dim
    ks = jax.random.split(key, 8)
    blocks = []
    for i in range(cfg.n_blocks):
        kk = jax.random.split(ks[2 + i], 5)
        blocks.append({
            "wq": truncated_normal(kk[0], (d, d), d ** -0.5, cfg.dtype),
            "wk": truncated_normal(kk[1], (d, d), d ** -0.5, cfg.dtype),
            "wv": truncated_normal(kk[2], (d, d), d ** -0.5, cfg.dtype),
            "wo": truncated_normal(kk[3], (d, d), d ** -0.5, cfg.dtype),
            "ff1": truncated_normal(kk[4], (d, 4 * d), d ** -0.5, cfg.dtype),
            "ff2": truncated_normal(kk[4], (4 * d, d), (4 * d) ** -0.5,
                                    cfg.dtype),
        })
    # target item + sequence, flattened into the MLP
    mlp_in = (cfg.seq_len + 1) * d
    return {
        "table": init_embedding(ks[0], cfg.embed, cfg.dtype),
        "pos": truncated_normal(ks[1], (cfg.seq_len + 1, d), 0.02,
                                cfg.dtype),
        "blocks": blocks,
        "mlp": _mlp_init(ks[-1], (mlp_in,) + cfg.mlp + (1,), cfg.dtype),
    }


def bst_forward(params: dict, cfg: BSTConfig, hist_ids: Array,
                target_id: Array) -> Array:
    """hist_ids: (B, seq) item ids; target_id: (B,) -> (B,) logits."""
    d = cfg.embed.dim
    hseq = jnp.take(params["table"], hist_ids, axis=0)       # (B, S, d)
    tgt = jnp.take(params["table"], target_id, axis=0)[:, None]
    x = jnp.concatenate([hseq, tgt], axis=1) + params["pos"][None]
    b, s, _ = x.shape
    nh, dh = cfg.n_heads, d // cfg.n_heads
    for blk in params["blocks"]:
        q = (x @ blk["wq"]).reshape(b, s, nh, dh)
        k = (x @ blk["wk"]).reshape(b, s, nh, dh)
        v = (x @ blk["wv"]).reshape(b, s, nh, dh)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (dh ** 0.5)
        p = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
        attn = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, d)
        x = x + attn @ blk["wo"]
        x = x + jax.nn.relu(x @ blk["ff1"]) @ blk["ff2"]
    return _mlp_apply(params["mlp"], x.reshape(b, -1))[:, 0]


# ---------------------------------------------------------------------------
# Two-tower retrieval (YouTube/RecSys'19 style)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str
    embed: EmbeddingSpec = EmbeddingSpec((), 256)  # [user_vocab, item_vocab]
    n_user_feats: int = 8
    n_item_feats: int = 4
    tower_mlp: tuple[int, ...] = (1024, 512, 256)
    dtype: object = jnp.float32


def twotower_init(key, cfg: TwoTowerConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed.dim
    return {
        "table": init_embedding(k1, cfg.embed, cfg.dtype),
        "user": _mlp_init(k2, (cfg.n_user_feats * d,) + cfg.tower_mlp,
                          cfg.dtype),
        "item": _mlp_init(k3, (cfg.n_item_feats * d,) + cfg.tower_mlp,
                          cfg.dtype),
    }


def _tower(mlp, emb: Array) -> Array:
    out = _mlp_apply(mlp, emb.reshape(emb.shape[0], -1))
    return out / jnp.maximum(
        jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6)


def user_embed(params: dict, cfg: TwoTowerConfig, user_ids: Array) -> Array:
    emb = embedding_lookup(params["table"], cfg.embed, user_ids)
    return _tower(params["user"], emb)


def item_embed(params: dict, cfg: TwoTowerConfig, item_ids: Array) -> Array:
    emb = embedding_lookup(params["table"], cfg.embed, item_ids,
                           feat_offset=cfg.n_user_feats)
    return _tower(params["item"], emb)


def twotower_scores(params: dict, cfg: TwoTowerConfig, user_ids: Array,
                    item_ids: Array) -> Array:
    """In-batch scoring matrix (B_u, B_i) of dot products."""
    u = user_embed(params, cfg, user_ids)
    it = item_embed(params, cfg, item_ids)
    return u @ it.T


def twotower_loss(params: dict, cfg: TwoTowerConfig, user_ids: Array,
                  item_ids: Array, temp: float = 0.05) -> Array:
    """In-batch sampled softmax (diagonal positives)."""
    s = twotower_scores(params, cfg, user_ids, item_ids) / temp
    logp = jax.nn.log_softmax(s.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.diagonal(logp))


def retrieval_scores(params: dict, cfg: TwoTowerConfig, user_ids: Array,
                     cand_vectors: Array, k: int = 100
                     ) -> tuple[Array, Array]:
    """retrieval_cand cell: 1 (or few) queries vs n_candidates
    precomputed item vectors -> top-k (scores, ids).  Batched dot, never
    a loop.  For the metric-index backend see repro.core.bruteforce /
    tree: d_cos = sqrt(1 - dot) is rank-equivalent and four-point (paper
    §5.5)."""
    u = user_embed(params, cfg, user_ids)            # (B, d)
    scores = u @ cand_vectors.T                      # (B, N)
    top, idx = jax.lax.top_k(scores, k)
    return top, idx


# BCE losses for the CTR models ------------------------------------------------

def bce_loss(logits: Array, labels: Array) -> Array:
    logits = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
