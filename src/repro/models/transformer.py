"""Decoder-only transformer (dense + MoE) — the 5 assigned LM archs.

Layer weights are stacked (L, ...) and consumed via lax.scan + remat;
HLO size is O(1) in depth (96-layer nemotron compiles like 16-layer
llama).  Three entry points per arch:

  train_step(params, opt_state, batch)  -> loss, new state
  prefill(params, tokens)               -> logits, kv_cache
  decode_step(params, cache, token, pos)-> logits, new cache

Sharding is annotated with logical PartitionSpecs from
repro.sharding.specs; GQA KV projections shard head_dim (kv_heads <
model axis — DESIGN.md §4), MoE experts shard per MoEConfig.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.sharding.specs import BATCH, constrain

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None          # default d_model // n_heads
    act: str = "silu"
    gated_mlp: bool = True
    moe: Optional[moe_lib.MoEConfig] = None
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_block: int = 1024

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/unembedding rows padded so the vocab dim shards over
        any <=512-chip mesh; padded logit columns are masked to -inf."""
        return ((self.vocab + 511) // 512) * 512

    @property
    def n_params(self) -> int:
        """Total parameter count (for 6ND MODEL_FLOPS)."""
        dh = self.head_dim
        attn = self.d_model * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe is not None:
            m = self.moe
            ff = m.n_experts * m.d_model * m.d_ff * (3 if m.gated else 2) \
                + self.d_model * m.n_experts
        else:
            ff = self.d_model * self.d_ff * (3 if self.gated_mlp else 2)
        per_layer = attn + ff + 2 * self.d_model
        return self.n_layers * per_layer + 2 * self.vocab * self.d_model \
            + self.d_model

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.n_params
        m = self.moe
        dh = self.head_dim
        attn = self.d_model * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        ff = m.top_k * m.d_model * m.d_ff * (3 if m.gated else 2) \
            + self.d_model * m.n_experts
        per_layer = attn + ff + 2 * self.d_model
        return self.n_layers * per_layer + 2 * self.vocab * self.d_model \
            + self.d_model


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(key, cfg: TransformerConfig) -> dict:
    nl, d, dh = cfg.n_layers, cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    vp = cfg.padded_vocab
    p = {
        "embed": L.truncated_normal(ks[0], (vp, d), 1.0, jnp.float32),
        "unembed": L.truncated_normal(ks[1], (d, vp), s, jnp.float32),
        "final_norm": jnp.ones((d,), jnp.float32),
        "attn": {
            "w_q": L.truncated_normal(ks[2], (nl, d, hq * dh), s, cfg.dtype),
            "w_k": L.truncated_normal(ks[3], (nl, d, hkv * dh), s, cfg.dtype),
            "w_v": L.truncated_normal(ks[4], (nl, d, hkv * dh), s, cfg.dtype),
            "w_o": L.truncated_normal(
                ks[5], (nl, hq * dh, d), (hq * dh) ** -0.5, cfg.dtype),
        },
        "norm1": jnp.ones((nl, d), jnp.float32),
        "norm2": jnp.ones((nl, d), jnp.float32),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(ks[6], nl, cfg.moe, cfg.dtype)
    else:
        p["mlp"] = L.init_mlp(ks[6], nl, d, cfg.d_ff, gated=cfg.gated_mlp,
                              dtype=cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# forward (training / prefill share the block; decode has its own)
# ---------------------------------------------------------------------------

def _layer_slice(p: dict, i) -> dict:
    return jax.tree_util.tree_map(lambda a: a[i], p)


def _block(cfg: TransformerConfig, lp: dict, x: Array, positions: Array
           ) -> tuple[Array, Array]:
    """One transformer layer on (B, S, D). Returns (out, aux_loss)."""
    b, s, d = x.shape
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    x = constrain(x, BATCH, None, None)
    h = L.rms_norm(x, lp["norm1"])
    q = (h @ lp["attn"]["w_q"].astype(h.dtype)).reshape(b, s, hq, dh)
    k = (h @ lp["attn"]["w_k"].astype(h.dtype)).reshape(b, s, hkv, dh)
    v = (h @ lp["attn"]["w_v"].astype(h.dtype)).reshape(b, s, hkv, dh)
    q = constrain(q, BATCH, None, "model", None)
    k = constrain(k, BATCH, None, None, None)
    v = constrain(v, BATCH, None, None, None)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    attn = L.flash_attention(q, k, v, causal=True,
                             block=min(cfg.attn_block, s))
    attn = constrain(attn, BATCH, None, "model", None)
    x = x + attn.reshape(b, s, hq * dh) @ lp["attn"]["w_o"].astype(x.dtype)

    h2 = L.rms_norm(x, lp["norm2"])
    if cfg.moe is not None:
        y, aux = moe_lib.moe_apply(lp["moe"], h2.reshape(b * s, d), cfg.moe)
        y = y.reshape(b, s, d)
    else:
        y = L.mlp_apply(lp["mlp"], h2, cfg.act)
        aux = jnp.zeros((), jnp.float32)
    return x + y, aux


def _mask_pad_logits(cfg: TransformerConfig, logits: Array) -> Array:
    """-inf the padded vocab columns (sampling/loss correctness)."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(valid, logits, -jnp.inf)


def forward(params: dict, cfg: TransformerConfig, tokens: Array) -> tuple[Array, Array]:
    """tokens (B, S) -> (logits (B, S, V), aux_loss)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = constrain(x, BATCH, None, None)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    layer_params = {
        "attn": params["attn"], "norm1": params["norm1"],
        "norm2": params["norm2"],
    }
    if cfg.moe is not None:
        layer_params["moe"] = params["moe"]
    else:
        layer_params["mlp"] = params["mlp"]

    def scan_body(carry, lp):
        x, aux = carry
        x, a = _block(cfg, lp, x, positions)
        return (x, aux + a), None

    body = jax.checkpoint(scan_body) if cfg.remat else scan_body
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), layer_params)
    x = L.rms_norm(x, params["final_norm"])
    logits = _mask_pad_logits(cfg, x.astype(jnp.float32)
                              @ params["unembed"])
    return logits, aux / cfg.n_layers


def loss_fn(params: dict, cfg: TransformerConfig, tokens: Array,
            targets: Array, aux_weight: float = 0.01) -> Array:
    logits, aux = forward(params, cfg, tokens)
    # Sharding-friendly cross entropy: take_along_axis over a
    # vocab-sharded logits tensor makes GSPMD all-gather the FULL logits
    # (537 GB for llama train_4k).  one-hot multiply + reduce keeps every
    # op sharded on vocab; only (B, S) partials cross the mesh.
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(m)
    # padded columns are -inf => exp 0; one_hot never selects them
    lse = jnp.log(jnp.sum(jnp.where(jnp.isfinite(shifted),
                                    jnp.exp(shifted), 0.0), axis=-1))
    onehot = jax.nn.one_hot(targets, cfg.padded_vocab, dtype=jnp.float32)
    picked = jnp.sum(jnp.where(jnp.isfinite(shifted), shifted, 0.0)
                     * onehot, axis=-1)
    nll = lse - picked
    return jnp.mean(nll) + aux_weight * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=None) -> dict:
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((), jnp.int32)}


def prefill(params: dict, cfg: TransformerConfig, tokens: Array
            ) -> tuple[Array, dict]:
    """Full-sequence forward that also materialises the KV cache.

    Returns (last-position logits (B, V), cache).
    """
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    layer_params = {
        "attn": params["attn"], "norm1": params["norm1"],
        "norm2": params["norm2"],
    }
    if cfg.moe is not None:
        layer_params["moe"] = params["moe"]
    else:
        layer_params["mlp"] = params["mlp"]

    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads

    def scan_body(x, lp):
        bb, ss, d = x.shape
        h = L.rms_norm(x, lp["norm1"])
        q = (h @ lp["attn"]["w_q"].astype(h.dtype)).reshape(bb, ss, hq, dh)
        k = (h @ lp["attn"]["w_k"].astype(h.dtype)).reshape(bb, ss, hkv, dh)
        v = (h @ lp["attn"]["w_v"].astype(h.dtype)).reshape(bb, ss, hkv, dh)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k_r = L.apply_rope(k, positions, cfg.rope_theta)
        attn = L.flash_attention(q, k_r, v, causal=True,
                                 block=min(cfg.attn_block, ss))
        x = x + attn.reshape(bb, ss, hq * dh) @ lp["attn"]["w_o"] \
            .astype(x.dtype)
        h2 = L.rms_norm(x, lp["norm2"])
        if cfg.moe is not None:
            y, _ = moe_lib.moe_apply(lp["moe"], h2.reshape(bb * ss, d),
                                     cfg.moe)
            y = y.reshape(bb, ss, d)
        else:
            y = L.mlp_apply(lp["mlp"], h2, cfg.act)
        return x + y, (k_r, v)

    body = jax.checkpoint(scan_body) if cfg.remat else scan_body
    x, (ks, vs) = jax.lax.scan(body, x, layer_params)
    x = L.rms_norm(x, params["final_norm"])
    logits = _mask_pad_logits(cfg, x[:, -1].astype(jnp.float32)
                              @ params["unembed"])
    cache = {"k": ks, "v": vs, "len": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(params: dict, cfg: TransformerConfig, cache: dict,
                token: Array) -> tuple[Array, dict]:
    """One-token decode.  token: (B,) int32; cache k/v:
    (L, B, S, Hkv, dh) with valid prefix cache['len'].

    Appends this step's K/V at position cache['len'] and attends over the
    (now len+1)-long prefix.  O(S) per token — the `long_500k` path.
    """
    b = token.shape[0]
    pos = cache["len"]
    x = params["embed"][token][:, None].astype(cfg.dtype)    # (B, 1, D)
    positions = jnp.full((b, 1), pos, jnp.int32)
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads

    layer_params = {
        "attn": params["attn"], "norm1": params["norm1"],
        "norm2": params["norm2"],
    }
    if cfg.moe is not None:
        layer_params["moe"] = params["moe"]
    else:
        layer_params["mlp"] = params["mlp"]
    kv = (cache["k"], cache["v"])

    def scan_body(x, xs):
        lp, k_cache, v_cache = xs
        bb, ss, d = x.shape
        h = L.rms_norm(x, lp["norm1"])
        q = (h @ lp["attn"]["w_q"].astype(h.dtype)).reshape(bb, 1, hq, dh)
        k = (h @ lp["attn"]["w_k"].astype(h.dtype)).reshape(bb, 1, hkv, dh)
        v = (h @ lp["attn"]["w_v"].astype(h.dtype)).reshape(bb, 1, hkv, dh)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
        attn = L.decode_attention(q, k_cache, v_cache, pos + 1)
        x = x + attn.reshape(bb, 1, hq * dh) @ lp["attn"]["w_o"] \
            .astype(x.dtype)
        h2 = L.rms_norm(x, lp["norm2"])
        if cfg.moe is not None:
            y, _ = moe_lib.moe_apply(lp["moe"], h2.reshape(bb, d), cfg.moe)
            y = y.reshape(bb, 1, d)
        else:
            y = L.mlp_apply(lp["mlp"], h2, cfg.act)
        return x + y, (k_cache, v_cache)

    x, (new_k, new_v) = jax.lax.scan(scan_body, x, (layer_params,) + kv)
    x = L.rms_norm(x, params["final_norm"])
    logits = _mask_pad_logits(cfg, x[:, 0].astype(jnp.float32)
                              @ params["unembed"])
    return logits, {"k": new_k, "v": new_v, "len": pos + 1}
