from repro.models import layers, transformer, moe, gnn, recsys  # noqa: F401
