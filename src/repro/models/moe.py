"""Mixture-of-Experts layer: top-k token-choice routing with grouped
GShard-style capacity dispatch (einsum form — MXU-friendly and
GSPMD-shardable; the production TPU layout).

Sharding (DESIGN.md §4):
  * expert-parallel when n_experts % model_axis == 0 (qwen3: 128/16=8
    experts per shard) — expert dim of w1/w2/w3 carries the "model" axis;
  * tensor-parallel experts otherwise (granite-moe: 40 experts, d_ff
    split over "model") — zero padding, zero waste.
The same einsum code serves both; only the PartitionSpecs differ.

Tokens are processed in groups (scan) so the (Tg, E, C) dispatch one-hots
stay VMEM/HBM-bounded for 1M-token batches.  Router in f32; aux
load-balancing loss (Switch) returned for the train loss.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import activation, truncated_normal
from repro.sharding.specs import BATCH, constrain, ctx_flag

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                  # per-expert hidden
    capacity_factor: float = 1.25
    group_size: int = 4096     # tokens per dispatch group
    gated: bool = True         # SwiGLU experts
    act: str = "silu"
    dispatch: str = "einsum"   # "einsum" (GShard one-hots) | "scatter"
    #                            (§Perf: kills the (Tg,E,C) masks)


def init_moe(key, n_layers: int, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    si, so = cfg.d_model ** -0.5, cfg.d_ff ** -0.5
    p = {
        "router": truncated_normal(
            ks[0], (n_layers, cfg.d_model, cfg.n_experts), si, jnp.float32),
        "w_up": truncated_normal(
            ks[1], (n_layers, cfg.n_experts, cfg.d_model, cfg.d_ff), si,
            dtype),
        "w_down": truncated_normal(
            ks[2], (n_layers, cfg.n_experts, cfg.d_ff, cfg.d_model), so,
            dtype),
    }
    if cfg.gated:
        p["w_gate"] = truncated_normal(
            ks[3], (n_layers, cfg.n_experts, cfg.d_model, cfg.d_ff), si,
            dtype)
    return p


def _capacity(cfg: MoEConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            // cfg.n_experts)
    return max(c, cfg.top_k)


def moe_apply(p_layer: dict, x: Array, cfg: MoEConfig
              ) -> tuple[Array, Array]:
    """x: (T, D) tokens -> (out (T, D), aux_loss ()).

    Grouped dispatch: reshape (G, Tg, D), scan groups; per group build
    top-k one-hot dispatch/combine tensors (Tg, E, C) and run experts as
    batched einsums.  Tokens over capacity are DROPPED (residual carries
    them — standard GShard semantics).
    """
    t, d = x.shape
    tg = min(cfg.group_size, t)
    assert t % tg == 0, (t, tg)
    g = t // tg
    cap = _capacity(cfg, tg)
    xg = x.reshape(g, tg, d)

    router = p_layer["router"].astype(jnp.float32)
    w_up = p_layer["w_up"]
    w_down = p_layer["w_down"]
    w_gate = p_layer.get("w_gate")
    act = activation(cfg.act)

    def group_step(_, xt):
        # ---- routing (f32) -------------------------------------------------
        logits = xt.astype(jnp.float32) @ router          # (Tg, E)
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, cfg.top_k)      # (Tg, k)
        topw = topw / jnp.maximum(
            jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

        # aux load-balance loss (Switch eq. 4-6)
        me = jnp.mean(probs, axis=0)                              # (E,)
        ce = jnp.mean(
            jax.nn.one_hot(topi[:, 0], cfg.n_experts, dtype=jnp.float32),
            axis=0)
        aux = cfg.n_experts * jnp.sum(me * ce)

        if cfg.dispatch == "scatter":
            return None, _scatter_group(cfg, xt, topw, topi, cap,
                                        w_up, w_down, w_gate, act, aux)

        # ---- capacity assignment ------------------------------------------
        # position of each (token, slot) within its expert, in routing
        # priority order (top-1 slots first — GShard convention).
        # Masks are built in the COMPUTE dtype (bf16): every (e, c) slot
        # receives exactly one token, so the dispatch/combine einsums
        # have single-term sums — bf16 masks are exact and halve the
        # dominant (Tg, E, C) traffic (§Perf iteration).
        mdt = xt.dtype
        disp = jnp.zeros((tg, cfg.n_experts, cap), mdt)
        comb = jnp.zeros((tg, cfg.n_experts, cap), mdt)
        fill = jnp.zeros((cfg.n_experts,), jnp.int32)
        for slot in range(cfg.top_k):
            e = topi[:, slot]                                     # (Tg,)
            onehot = jax.nn.one_hot(e, cfg.n_experts, dtype=jnp.int32)
            pos = fill[None, :] + jnp.cumsum(onehot, axis=0) - onehot
            ppos = jnp.sum(pos * onehot, axis=-1)                 # (Tg,)
            keep = ppos < cap
            slot_disp = (
                jax.nn.one_hot(e, cfg.n_experts, dtype=mdt)[:, :, None]
                * jax.nn.one_hot(ppos, cap, dtype=mdt)[:, None, :]
                * keep[:, None, None].astype(mdt))
            disp = disp + slot_disp
            comb = comb + slot_disp * topw[:, slot][:, None, None] \
                .astype(mdt)
            fill = fill + jnp.sum(onehot, axis=0)

        # ---- expert compute -------------------------------------------
        # EP: experts over "model" (dispatch einsum = the all-to-all);
        # TP: per-expert ffn dim over "model".
        ep = ctx_flag("moe_ep")
        xe = jnp.einsum("tec,td->ecd", disp, xt)
        if ep is True:
            xe = constrain(xe, "model", None, None)
        up = jnp.einsum("ecd,edf->ecf", xe, w_up.astype(xt.dtype))
        if w_gate is not None:
            gate = act(jnp.einsum("ecd,edf->ecf", xe,
                                  w_gate.astype(xt.dtype)))
            hidden = gate * up
        else:
            hidden = act(up)
        if ep is True:
            hidden = constrain(hidden, "model", None, None)
        elif ep is False:
            hidden = constrain(hidden, None, None, "model")
        ye = jnp.einsum("ecf,efd->ecd", hidden, w_down.astype(xt.dtype))
        yt = jnp.einsum("tec,ecd->td", comb, ye)
        yt = constrain(yt, BATCH, None)
        return None, (yt, aux)

    _, (yg, auxes) = jax.lax.scan(group_step, None, xg)
    return yg.reshape(t, d), jnp.mean(auxes)


def _scatter_group(cfg: MoEConfig, xt: Array, topw: Array, topi: Array,
                   cap: int, w_up, w_down, w_gate, act, aux):
    """Scatter/gather dispatch (§Perf): no (Tg, E, C) one-hot masks.

    Position-in-expert via a single (k*Tg, E) int32 cumsum in slot-major
    order (top-1 assignments claim capacity first — GShard priority);
    dispatch is a scatter-add into (E, C, D); combine is a gather +
    segment-sum.  Traffic per group: O(k*Tg*D + E*C*D) instead of
    O(k*Tg*E*C).
    """
    tg, d = xt.shape
    k = cfg.top_k
    e_flat = topi.T.reshape(-1)                       # (k*Tg,) slot-major
    w_flat = topw.T.reshape(-1)
    tok_flat = jnp.tile(jnp.arange(tg, dtype=jnp.int32), k)

    onehot = jax.nn.one_hot(e_flat, cfg.n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot         # (kTg, E)
    ppos = jnp.sum(pos * onehot, axis=-1)             # (kTg,)
    keep = ppos < cap
    ppos_c = jnp.where(keep, ppos, cap - 1)

    rows = xt[tok_flat] * (keep.astype(xt.dtype))[:, None]
    x_disp = jnp.zeros((cfg.n_experts, cap, d), xt.dtype)
    x_disp = x_disp.at[e_flat, ppos_c].add(rows, mode="drop")

    up = jnp.einsum("ecd,edf->ecf", x_disp, w_up.astype(xt.dtype))
    if w_gate is not None:
        gate = act(jnp.einsum("ecd,edf->ecf", x_disp,
                              w_gate.astype(xt.dtype)))
        hidden = gate * up
    else:
        hidden = act(up)
    ye = jnp.einsum("ecf,efd->ecd", hidden, w_down.astype(xt.dtype))

    y_rows = ye[e_flat, ppos_c] * (w_flat * keep).astype(xt.dtype)[:, None]
    yt = jax.ops.segment_sum(y_rows, tok_flat, tg)
    yt = constrain(yt.astype(xt.dtype), BATCH, None)
    return yt, aux
