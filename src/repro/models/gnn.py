"""PNA (Principal Neighbourhood Aggregation, arXiv:2004.05718) in JAX.

Message passing is segment-reduce over an edge list (JAX has no sparse
SpMM for this: ``segment_sum``/``segment_max`` over edge-index gathers IS
the implementation — kernel_taxonomy §GNN).

Aggregators: mean / max / min / std;  scalers: identity / amplification
log(d+1)/delta / attenuation delta/log(d+1)  (the paper's canonical set).

Shapes served:
  full_graph_sm / ogb_products : full-batch (N, E) arrays
  minibatch_lg                 : padded sampled blocks from data.sampler
  molecule                     : batched small graphs via graph_ids
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import truncated_normal

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 1433
    n_classes: int = 40
    delta: float = 2.5            # avg log-degree normaliser
    dtype: object = jnp.float32


N_AGG = 4      # mean, max, min, std
N_SCALE = 3    # id, amplification, attenuation


def init_params(key, cfg: PNAConfig) -> dict:
    ks = jax.random.split(key, 2 + cfg.n_layers * 2)
    d, h = cfg.d_in, cfg.d_hidden
    p = {"enc": truncated_normal(ks[0], (d, h), d ** -0.5, cfg.dtype),
         "dec": truncated_normal(ks[1], (h, cfg.n_classes), h ** -0.5,
                                 cfg.dtype),
         "layers": []}
    fan_in = h * (1 + N_AGG * N_SCALE)
    for i in range(cfg.n_layers):
        p["layers"].append({
            "w_msg": truncated_normal(ks[2 + 2 * i], (2 * h, h),
                                      (2 * h) ** -0.5, cfg.dtype),
            "w_upd": truncated_normal(ks[3 + 2 * i], (fan_in, h),
                                      fan_in ** -0.5, cfg.dtype),
        })
    return p


def _aggregate(msg: Array, dst: Array, n_nodes: int) -> tuple[Array, Array]:
    """msg (E, H) scattered to dst -> (agg (N, 4H), degree (N,))."""
    ones = jnp.ones((msg.shape[0],), msg.dtype)
    deg = jax.ops.segment_sum(ones, dst, n_nodes)
    deg_safe = jnp.maximum(deg, 1.0)

    s = jax.ops.segment_sum(msg, dst, n_nodes)
    mean = s / deg_safe[:, None]
    mx = jax.ops.segment_max(msg, dst, n_nodes)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    mn = -jax.ops.segment_max(-msg, dst, n_nodes)
    mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
    s2 = jax.ops.segment_sum(msg * msg, dst, n_nodes)
    var = jnp.maximum(s2 / deg_safe[:, None] - mean * mean, 0.0)
    std = jnp.sqrt(var + 1e-8)
    return jnp.concatenate([mean, mx, mn, std], axis=-1), deg


def _scale(agg: Array, deg: Array, delta: float) -> Array:
    """(N, 4H) -> (N, 12H) with identity/amplify/attenuate scalers."""
    logd = jnp.log1p(deg)[:, None]
    amp = logd / delta
    att = delta / jnp.maximum(logd, 1e-6)
    return jnp.concatenate([agg, agg * amp, agg * att], axis=-1)


def forward(params: dict, cfg: PNAConfig, x: Array, src: Array, dst: Array,
            edge_mask: Optional[Array] = None,
            graph_ids: Optional[Array] = None,
            n_graphs: int = 0) -> Array:
    """x: (N, d_in); src/dst: (E,) int32; edge_mask: (E,) for padded
    minibatch blocks.  graph_ids + n_graphs: per-graph pooling (molecule
    cells) — else returns per-node logits.
    """
    n = x.shape[0]
    h = x.astype(cfg.dtype) @ params["enc"]
    for lp in params["layers"]:
        hs = h[src]
        hd = h[dst]
        msg = jax.nn.relu(
            jnp.concatenate([hs, hd], axis=-1) @ lp["w_msg"])
        if edge_mask is not None:
            msg = msg * edge_mask[:, None].astype(msg.dtype)
            dst_eff = jnp.where(edge_mask, dst, n)   # scatter pad -> bin n
        else:
            dst_eff = dst
        agg, deg = _aggregate(msg, dst_eff, n + 1)
        agg, deg = agg[:n], deg[:n]
        feats = jnp.concatenate([h, _scale(agg, deg, cfg.delta)], axis=-1)
        h = jax.nn.relu(feats @ lp["w_upd"]) + h     # residual
    if graph_ids is not None:
        pooled = jax.ops.segment_sum(h, graph_ids, n_graphs)
        cnt = jax.ops.segment_sum(jnp.ones((n,), h.dtype), graph_ids,
                                  n_graphs)
        h = pooled / jnp.maximum(cnt, 1.0)[:, None]
    return h @ params["dec"]


def loss_fn(params: dict, cfg: PNAConfig, x, src, dst, labels,
            edge_mask=None, label_mask=None, graph_ids=None,
            n_graphs: int = 0) -> Array:
    logits = forward(params, cfg, x, src, dst, edge_mask, graph_ids,
                     n_graphs)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    if label_mask is not None:
        return jnp.sum(nll * label_mask) / jnp.maximum(
            jnp.sum(label_mask), 1.0)
    return jnp.mean(nll)
