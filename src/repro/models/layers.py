"""Shared neural layers (pure JAX, param pytrees, no framework deps).

Conventions:
  * params are nested dicts of jnp arrays; init fns return the pytree.
  * per-layer weights are STACKED on a leading L axis and consumed by
    lax.scan — keeps HLO size O(1) in depth (critical for the 96-layer
    dry-runs) and is the idiomatic production layout.
  * compute dtype bf16, params f32 (cast on use), f32 softmax/norms.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.specs import BATCH, constrain, ctx_flag

Array = jnp.ndarray


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma).astype(x.dtype)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    if name == "squared_relu":           # Primer / nemotron-4
        return lambda x: jnp.square(jax.nn.relu(x))
    raise KeyError(name)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, H, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA; flash-style scan for train/prefill; cache for decode)
# ---------------------------------------------------------------------------

def _repeat_kv(k: Array, groups: int) -> Array:
    """(B, S, Hkv, dh) -> (B, S, Hkv*groups, dh) by head repetition."""
    if groups == 1:
        return k
    b, s, h, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, dh)) \
        .reshape(b, s, h * groups, dh)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool,
                    block: int = 1024) -> Array:
    """Online-softmax attention, scanned over KV blocks.

    q: (B, Sq, H, dh); k, v: (B, Sk, Hkv, dh); GQA via head repetition of
    the (small) K/V blocks inside the loop.  Memory per step is
    O(B*H*Sq*block) instead of O(B*H*Sq*Sk).  Each block step is
    checkpointed so scan's backward recomputes rather than storing block
    scores.
    """
    b, sq, h, dh = q.shape
    _, sk, hkv, _ = k.shape
    groups = h // hkv
    scale = 1.0 / (dh ** 0.5)
    sk_pad = ((sk + block - 1) // block) * block
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    nblk = sk_pad // block

    qf = (q * scale).astype(jnp.float32)
    kb = k.reshape(b, nblk, block, hkv, dh)
    vb = v.reshape(b, nblk, block, hkv, dh)
    q_pos = jnp.arange(sq)

    def step(carry, xs):
        acc, m, l = carry
        kv_idx, k_blk, v_blk = xs
        k_blk = _repeat_kv(k_blk, groups).astype(jnp.float32)
        v_blk = _repeat_kv(v_blk, groups).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_blk)     # (B, H, Sq, blk)
        k_pos = kv_idx * block + jnp.arange(block)
        if causal:
            mask = (q_pos[:, None] >= k_pos[None, :]) \
                & (k_pos < sk)[None, :]
            s = jnp.where(mask[None, None], s, -jnp.inf)
        elif sk_pad != sk:
            s = jnp.where((k_pos < sk)[None, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard -inf rows (fully masked block): exp(-inf - -inf) -> 0
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_blk)
        return (acc_new, m_new, l_new), None

    # anchor the scan-carry sharding: batch over the data axes, heads over
    # "model" (GSPMD's fixpoint otherwise replicates batch inside the
    # layer scan — measured 16x attention memory on the 16x16 mesh)
    acc0 = constrain(jnp.zeros((b, h, sq, dh), jnp.float32),
                     BATCH, "model", None, None)
    m0 = constrain(jnp.full((b, h, sq), -jnp.inf, jnp.float32),
                   BATCH, "model", None)
    l0 = constrain(jnp.zeros((b, h, sq), jnp.float32),
                   BATCH, "model", None)
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(step), (acc0, m0, l0),
        (jnp.arange(nblk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return jnp.einsum("bhqd->bqhd", out).astype(q.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cache_len: Array) -> Array:
    """Single-token attention against a cache.

    q: (B, 1, H, dh); caches: (B, S, Hkv, dh); cache_len: () or (B,) valid
    prefix length.  O(S) — this is what makes `long_500k` decode cells
    runnable for full-attention archs (DESIGN.md §4).
    """
    b, _, h, dh = q.shape
    _, s, hkv, _ = k_cache.shape
    groups = h // hkv
    scale = 1.0 / (dh ** 0.5)
    kf = _repeat_kv(k_cache, groups).astype(jnp.float32)
    vf = _repeat_kv(v_cache, groups).astype(jnp.float32)
    qf = (q[:, 0] * scale).astype(jnp.float32)            # (B, H, dh)
    scores = jnp.einsum("bhd,bshd->bhs", qf, kf)
    # long-context decode: cache (and thus scores) sequence-sharded over
    # the data axes (flash-decoding); 32k decode: batch-sharded.
    if ctx_flag("long_context", False):
        scores = constrain(scores, None, "model", BATCH)
    else:
        scores = constrain(scores, BATCH, "model", None)
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    scores = jnp.where(valid[:, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, vf)
    return out[:, None].astype(q.dtype)                   # (B, 1, H, dh)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, n_layers: int, d_model: int, d_ff: int, *,
             gated: bool, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "w_up": truncated_normal(ks[0], (n_layers, d_model, d_ff),
                                 scale_in, dtype),
        "w_down": truncated_normal(ks[1], (n_layers, d_ff, d_model),
                                   scale_out, dtype),
    }
    if gated:
        p["w_gate"] = truncated_normal(ks[2], (n_layers, d_model, d_ff),
                                       scale_in, dtype)
    return p


def mlp_apply(p_layer: dict, x: Array, act_name: str) -> Array:
    """p_layer: single-layer slice (no leading L)."""
    act = activation(act_name)
    up = x @ p_layer["w_up"].astype(x.dtype)
    if "w_gate" in p_layer:
        gate = act(x @ p_layer["w_gate"].astype(x.dtype))
        hidden = gate * up
    else:
        hidden = act(up)
    if hidden.ndim == 3:
        hidden = constrain(hidden, BATCH, None, "model")
    return hidden @ p_layer["w_down"].astype(x.dtype)
