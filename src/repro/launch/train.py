"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 200 --reduced --ckpt-dir /tmp/ckpt

--reduced runs the smoke-sized config (CPU-runnable, used by the
examples and the end-to-end driver); the full config is what the
dry-run lowers for the production meshes.  On a real cluster this same
entry point runs under `jax.distributed.initialize()` with the
production mesh (see repro.launch.mesh / dryrun for the sharding).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.data import synthetic
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, train_loop


def _lm_setup(mod, reduced: bool, batch: int, seq: int):
    from repro.models import transformer as T
    cfg = mod.reduced_config() if reduced else mod.full_config()

    def loss_fn(params, b):
        return T.loss_fn(params, cfg, b["tokens"], b["targets"])

    def make_batch(step):
        b = synthetic.token_batch(0, step, batch, seq, cfg.vocab)
        return {k: jnp.asarray(v) for k, v in b.items()}

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return params, loss_fn, make_batch


def _recsys_setup(mod, reduced: bool, batch: int):
    from repro.models import recsys as R
    cfg = mod.reduced_config() if reduced else mod.full_config()
    arch = mod.ARCH

    if arch == "two-tower-retrieval":
        def loss_fn(params, b):
            return R.twotower_loss(params, cfg, b["user_ids"],
                                   b["item_ids"])

        def make_batch(step):
            b = synthetic.retrieval_batch(
                0, step, batch, cfg.n_user_feats, cfg.n_item_feats,
                cfg.embed.vocab_sizes[0],
                cfg.embed.vocab_sizes[cfg.n_user_feats])
            return {k: jnp.asarray(v) for k, v in b.items()}

        params = R.twotower_init(jax.random.PRNGKey(0), cfg)
        return params, loss_fn, make_batch

    fwd = {"dlrm-mlperf": R.dlrm_forward, "dcn-v2": R.dcn_forward}.get(arch)
    if fwd is not None:
        def loss_fn(params, b):
            return R.bce_loss(fwd(params, cfg, b["dense"], b["sparse_ids"]),
                              b["labels"])

        def make_batch(step):
            b = synthetic.click_batch(0, step, batch, cfg.n_dense,
                                      cfg.embed.vocab_sizes)
            return {k: jnp.asarray(v) for k, v in b.items()}

        init = {"dlrm-mlperf": R.dlrm_init, "dcn-v2": R.dcn_init}[arch]
        params = init(jax.random.PRNGKey(0), cfg)
        return params, loss_fn, make_batch

    # bst
    def loss_fn(params, b):
        return R.bce_loss(
            R.bst_forward(params, cfg, b["hist_ids"], b["target_id"]),
            b["labels"])

    def make_batch(step):
        b = synthetic.click_batch(0, step, batch, 1, (64,),
                                  seq_len=cfg.seq_len)
        out = {"hist_ids": jnp.asarray(b["hist_ids"]) %
               cfg.embed.vocab_sizes[0],
               "target_id": jnp.asarray(b["target_id"]) %
               cfg.embed.vocab_sizes[0],
               "labels": jnp.asarray(b["labels"])}
        return out

    params = R.bst_init(jax.random.PRNGKey(0), cfg)
    return params, loss_fn, make_batch


def _gnn_setup(mod, reduced: bool, batch: int):
    from repro.models import gnn
    cfg = mod.reduced_config() if reduced else mod.full_config()
    g = synthetic.random_graph(0, 2000 if reduced else 100000,
                               12000 if reduced else 800000, cfg.d_in,
                               n_classes=cfg.n_classes)
    x = jnp.asarray(g["x"])
    src = jnp.asarray(g["src"])
    dst = jnp.asarray(g["dst"])
    labels = jnp.asarray(g["labels"])

    def loss_fn(params, b):
        del b
        return gnn.loss_fn(params, cfg, x, src, dst, labels)

    def make_batch(step):
        return {"step": jnp.asarray(step)}

    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    return params, loss_fn, make_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    mod = get(args.arch)
    fam = mod.FAMILY
    if fam == "lm":
        params, loss_fn, make_batch = _lm_setup(
            mod, args.reduced, args.batch, args.seq)
    elif fam == "recsys":
        params, loss_fn, make_batch = _recsys_setup(
            mod, args.reduced, args.batch)
    elif fam == "gnn":
        params, loss_fn, make_batch = _gnn_setup(
            mod, args.reduced, args.batch)
    else:
        raise SystemExit(f"arch family {fam} is served, not trained "
                         "(see repro.launch.serve)")

    cfg = TrainLoopConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir, log_every=max(args.steps // 10, 1),
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=args.steps // 10,
                              total_steps=args.steps))
    params, _, hist = train_loop(loss_fn, params, make_batch, cfg)
    for h in hist:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  {h['sec']*1e3:.0f} ms")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
