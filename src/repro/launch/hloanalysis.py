"""Loop-aware roofline analysis of optimized HLO text.

xla::HloCostAnalysis visits while-loop bodies ONCE, so any scan-based
model (layers, flash blocks, MoE groups) under-reports FLOPs/bytes by
the trip count.  This analyzer parses the optimized HLO text, builds the
computation call graph, extracts while trip counts (backend_config
known_trip_count, else the condition's `compare(iv, constant)` bound),
and accumulates per-computation:

  * dot_flops        2 * prod(result dims) * prod(contracting dims)
  * traffic_bytes    sum of result-tensor bytes of top-level ops
                     (fusion internals excluded = materialised tensors)
  * collective bytes per type (all-reduce / all-gather / reduce-scatter
                     / all-to-all / collective-permute), result sizes

each scaled by the product of enclosing trip counts.

This is the container-grade stand-in for a real profiler: exact on loop
structure, approximate on elementwise FLOPs (dots dominate every cell
here) and on re-read traffic (each tensor counted once, where produced).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
               "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*->")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*"
                    r"(\([^)]*\)|[\w\[\],\{\}]+?)\s+([\w-]+)\(")
_CALLED = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)="
    r"(\{[^}]*\}|%?[\w\.\-_]+)")
_TRIP_BC = re.compile(r'known_trip_count[\"\':\s{]+n[\"\':\s]+(\d+)')
_CONST_RE = re.compile(r"%?([\w\.\-_]+)\s*=\s*s(?:32|64)\[\]\s+"
                       r"constant\((\d+)\)")
_CMP_RE = re.compile(r"compare\(([^)]*)\)[^\n]*direction=(\w+)")


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Computation:
    name: str
    lines: list

    dot_flops: float = 0.0
    traffic: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    # (callee, kind, trip, line) edges
    calls: list = dataclasses.field(default_factory=list)
    constants: dict = dataclasses.field(default_factory=dict)


def _parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m and s.endswith("{"):
                cur = Computation(m.group(1), [])
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(s)
    return comps


def _split_top_level(inner: str) -> list[str]:
    """Split an HLO operand list on commas at bracket depth 0 only —
    inline shapes (`f32[128,16,64] %x`) contain commas themselves."""
    parts, cur, depth = [], [], 0
    for ch in inner:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


def _dot_flops_of_line(s: str, types: dict[str, str]) -> float:
    m = _OP_RE.match(s)
    if not m or m.group(3) != "dot":
        return 0.0
    result_dims = _dims_of(m.group(2))
    # operand shapes: inline in the args if present, else resolved from
    # the computation's name -> type map
    inner = s[s.index("dot(") + 4:]
    inner = inner[:inner.index(")")]
    lhs_arg = _split_top_level(inner)[0].strip()
    lhs_m = _SHAPE_RE.search(lhs_arg)
    if lhs_m is not None:
        lhs_dims = _dims_of(lhs_m.group(0))
    else:
        nm = lhs_arg.lstrip("%")
        t = types.get(nm)
        if t is None:
            return 0.0
        lhs_dims = _dims_of(t)
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", s)
    contract = 1
    if cd and cd.group(1):
        for d in cd.group(1).split(","):
            contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
    out = 1
    for d in result_dims:
        out *= d
    return 2.0 * out * contract


def _analyze_comp(c: Computation):
    types: dict[str, str] = {}
    for s in c.lines:
        m = _OP_RE.match(s)
        if m:
            types[m.group(1)] = m.group(2)
    for s in c.lines:
        mconst = _CONST_RE.match(s)
        if mconst:
            c.constants[mconst.group(1)] = int(mconst.group(2))
        m = _OP_RE.match(s)
        if not m:
            continue
        type_str, opname = m.group(2), m.group(3)
        _, rbytes = _shape_elems_bytes(type_str)
        if opname == "dot":
            c.dot_flops += _dot_flops_of_line(s, types)
        for coll in COLLECTIVES:
            if opname == coll or opname == coll + "-start":
                c.coll[coll] += rbytes
        if opname not in ("parameter", "constant", "tuple",
                          "get-tuple-element", "bitcast"):
            c.traffic += rbytes
        # call edges
        for grp in _CALLED.findall(s):
            names = re.findall(r"%?([\w\.\-_]+)", grp)
            kind = opname
            trip = None
            if opname == "while":
                mt = _TRIP_BC.search(s)
                if mt:
                    trip = int(mt.group(1))
            for nm in names:
                c.calls.append((nm, kind, trip, s))


def _trip_from_condition(cond: Computation) -> int | None:
    """Parse `compare(%iv, %c), direction=LT` with %c = constant(N)."""
    for s in cond.lines:
        m = _CMP_RE.search(s)
        if not m:
            continue
        args = re.findall(r"%?([\w\.\-_]+)", m.group(1))
        for a in args:
            if a in cond.constants:
                return cond.constants[a]
    # constants may live in the caller; fall back to any constant compare
    return None


def analyze(hlo: str) -> dict:
    comps = _parse_computations(hlo)
    for c in comps.values():
        _analyze_comp(c)

    entry_name = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if s.startswith("ENTRY"):
            m = _COMP_HDR.match(s)
            if m:
                entry_name = m.group(1)
            break
    if entry_name is None or entry_name not in comps:
        # fall back: biggest computation
        entry_name = max(comps, key=lambda k: len(comps[k].lines))

    totals = defaultdict(float)
    coll_tot = {c: 0.0 for c in COLLECTIVES}
    visited_stack = []

    def visit(name: str, mult: float, in_fusion: bool):
        if name not in comps or name in visited_stack:
            return
        visited_stack.append(name)
        c = comps[name]
        totals["dot_flops"] += mult * c.dot_flops
        if not in_fusion:
            # fusion/reduction-lambda internals live in registers/VMEM;
            # only the fusion RESULT (counted at its call site) is HBM
            # traffic.  Counting internals here double-counted scan-body
            # stacks by ~10x on the MoE cells.
            totals["traffic"] += mult * c.traffic
        for k in COLLECTIVES:
            coll_tot[k] += mult * c.coll[k]
        handled_conditions = set()
        for callee, kind, trip, s in c.calls:
            is_real = ("body=" in s or "condition=" in s
                       or "branch_computations=" in s or kind == "call")
            if kind == "while":
                body = re.search(r"body=%?([\w\.\-_]+)", s)
                cond = re.search(r"condition=%?([\w\.\-_]+)", s)
                t = trip
                if t is None and cond and cond.group(1) in comps:
                    t = _trip_from_condition(comps[cond.group(1)])
                t = t if t else 1
                if body and callee == body.group(1):
                    visit(callee, mult * t, in_fusion)
                elif cond and callee == cond.group(1):
                    if callee not in handled_conditions:
                        visit(callee, mult * (t + 1), in_fusion)
                        handled_conditions.add(callee)
            else:
                visit(callee, mult, in_fusion or not is_real)
        visited_stack.pop()

    visit(entry_name, 1.0, False)
    totals["collective_bytes"] = sum(coll_tot.values())
    return {
        "dot_flops": totals["dot_flops"],
        "traffic_bytes": totals["traffic"],
        "collective_bytes": totals["collective_bytes"],
        "collectives": coll_tot,
    }
