"""Retrieval serving launcher — the paper's technique in the serving
path.

  PYTHONPATH=src python -m repro.launch.serve --n 40000 --dim 24 \
      --queries 64 --backend both

Backends:
  bruteforce : MXU pairwise scan + top-k (the dry-run `retrieval_cand`
               lowering)
  index      : MHT metric index with Hilbert Exclusion (d_cos space)
  both       : run both, assert identical results, report the distance-
               evaluation saving (the paper's cost metric)
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import bruteforce
from repro.core.tree import build_mht, search_binary_tree
from repro.data.synthetic import metric_space


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=40000)
    ap.add_argument("--dim", type=int, default=24)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--threshold-sel", type=float, default=1e-4,
                    help="range-query selectivity")
    ap.add_argument("--backend", default="both",
                    choices=["bruteforce", "index", "both"])
    ap.add_argument("--mechanism", default="hilbert",
                    choices=["hilbert", "hyperbolic"])
    args = ap.parse_args()

    pts = metric_space(0, args.n + args.queries, args.dim, clustered=16)
    data, queries = pts[:args.n], pts[args.n:]
    # calibrate a threshold at the requested selectivity
    from repro.core import metrics as metrics_lib
    m = metrics_lib.get("euclidean")
    sample = np.asarray(m.pairwise(queries[:32], data[:8192])).reshape(-1)
    t = float(np.quantile(sample, args.threshold_sel))
    print(f"serving n={args.n} dim={args.dim} queries={args.queries} "
          f"t={t:.4f}")

    res_bf = res_ix = None
    if args.backend in ("bruteforce", "both"):
        t0 = time.time()
        cnt, res_bf = bruteforce.range_search(data, queries, t,
                                              metric_name="euclidean")
        print(f"bruteforce: {time.time()-t0:.2f}s  "
              f"n_dist/query={args.n}  hits={int(cnt.sum())}")

    if args.backend in ("index", "both"):
        t0 = time.time()
        tree = build_mht(data, "euclidean", leaf_size=32, seed=0)
        print(f"index build: {time.time()-t0:.2f}s")
        t0 = time.time()
        st = search_binary_tree(tree, queries, t, metric_name="euclidean",
                                mechanism=args.mechanism, r_cap=1024)
        if np.asarray(st.stack_overflow).any():
            raise RuntimeError(
                "traversal stack overflow: raise stack_cap / lower frontier")
        if np.asarray(st.overflow).any():
            raise RuntimeError("result buffer overflow: raise r_cap")
        res_ix = st.result_sets()
        nd = float(np.mean(np.asarray(st.n_dist)))
        print(f"index search ({args.mechanism}): {time.time()-t0:.2f}s  "
              f"n_dist/query={nd:.0f}  "
              f"({100*nd/args.n:.2f}% of brute force)")

    if res_bf is not None and res_ix is not None:
        assert res_bf == res_ix, "result sets differ!"
        print("results identical across backends (paper §6.5)")


if __name__ == "__main__":
    main()
