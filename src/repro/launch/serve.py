"""Retrieval serving launcher — the paper's technique in the serving
path.

  PYTHONPATH=src python -m repro.launch.serve --n 40000 --dim 24 \
      --queries 64 --backend both --metric euclidean

Backends:
  bruteforce : MXU pairwise scan + top-k (the dry-run `retrieval_cand`
               lowering)
  index      : MHT metric index with the selected exclusion mechanism,
               range queries at a calibrated selectivity
  both       : run bruteforce + index, assert identical results, report
               the distance-evaluation saving (the paper's cost metric)
  knn        : exact k-NN from the MHT shrinking-radius engine,
               cross-checked against ``bruteforce.knn`` (ids and
               distances)

``--metric`` selects the distance (any registered metric, see
``repro.core.metrics.names()``); simplex metrics (jsd / triangular) get
their inputs row-normalised automatically.  ``hilbert`` requires the
four-point property and is rejected otherwise — pass
``--mechanism hyperbolic`` for metrics like manhattan/chebyshev.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import bruteforce
from repro.core import metrics as metrics_lib
from repro.core.tree import (build_mht, check_complete,
                             knn_search_binary_tree, search_binary_tree)
from repro.data.synthetic import metric_space


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=40000)
    ap.add_argument("--dim", type=int, default=24)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--threshold-sel", type=float, default=1e-4,
                    help="range-query selectivity")
    ap.add_argument("--backend", default="both",
                    choices=["bruteforce", "index", "both", "knn"])
    ap.add_argument("--metric", default="euclidean",
                    choices=metrics_lib.names(),
                    help="distance metric for data, index and queries")
    ap.add_argument("--mechanism", default="hilbert",
                    choices=["hilbert", "hyperbolic"])
    ap.add_argument("--k", type=int, default=10,
                    help="neighbours per query (knn backend)")
    args = ap.parse_args()

    m = metrics_lib.get(args.metric)
    pts = metric_space(0, args.n + args.queries, args.dim, clustered=16,
                       simplex=m.simplex)
    data, queries = pts[:args.n], pts[args.n:]

    if args.backend == "knn":
        t0 = time.time()
        tree = build_mht(data, args.metric, leaf_size=32, seed=0)
        print(f"index build: {time.time()-t0:.2f}s")
        t0 = time.time()
        st = knn_search_binary_tree(tree, queries, args.k,
                                    metric_name=args.metric,
                                    mechanism=args.mechanism)
        check_complete(st, context="serve knn")
        nd = float(np.mean(np.asarray(st.n_dist)))
        print(f"index knn ({args.mechanism}, k={args.k}): "
              f"{time.time()-t0:.2f}s  n_dist/query={nd:.0f}  "
              f"({100*nd/args.n:.2f}% of brute force)")
        t0 = time.time()
        bf_d, bf_i = bruteforce.knn(np.asarray(data), np.asarray(queries),
                                    metric_name=args.metric, k=args.k)
        print(f"bruteforce knn: {time.time()-t0:.2f}s  "
              f"n_dist/query={args.n}")
        assert np.array_equal(np.asarray(st.ids), np.asarray(bf_i)), \
            "knn ids differ from brute force!"
        np.testing.assert_allclose(np.asarray(st.dists), np.asarray(bf_d),
                                   atol=1e-5, rtol=1e-5)
        print("knn results identical across backends")
        return

    # calibrate a threshold at the requested selectivity
    sample = np.asarray(m.pairwise(queries[:32], data[:8192])).reshape(-1)
    t = float(np.quantile(sample, args.threshold_sel))
    print(f"serving n={args.n} dim={args.dim} queries={args.queries} "
          f"metric={args.metric} t={t:.4f}")

    res_bf = res_ix = None
    if args.backend in ("bruteforce", "both"):
        t0 = time.time()
        cnt, res_bf = bruteforce.range_search(data, queries, t,
                                              metric_name=args.metric)
        print(f"bruteforce: {time.time()-t0:.2f}s  "
              f"n_dist/query={args.n}  hits={int(cnt.sum())}")

    if args.backend in ("index", "both"):
        t0 = time.time()
        tree = build_mht(data, args.metric, leaf_size=32, seed=0)
        print(f"index build: {time.time()-t0:.2f}s")
        t0 = time.time()
        st = search_binary_tree(tree, queries, t, metric_name=args.metric,
                                mechanism=args.mechanism, r_cap=1024)
        check_complete(st, context="serve index")
        res_ix = st.result_sets()
        nd = float(np.mean(np.asarray(st.n_dist)))
        print(f"index search ({args.mechanism}): {time.time()-t0:.2f}s  "
              f"n_dist/query={nd:.0f}  "
              f"({100*nd/args.n:.2f}% of brute force)")

    if res_bf is not None and res_ix is not None:
        assert res_bf == res_ix, "result sets differ!"
        print("results identical across backends (paper §6.5)")


if __name__ == "__main__":
    main()
