import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms (DESIGN.md §6).

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --cell llama3.2-1b:train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --report   # print table

Results land incrementally in results/dryrun/<mesh>/<arch>__<shape>.json
so a crash never loses completed cells.

The XLA_FLAGS line above MUST run before any jax import (device count is
locked at first backend init) — hence its position as line 1-2.
"""

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import ARCH_IDS, get            # noqa: E402
from repro.launch import hloanalysis               # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.sharding.specs import named_sharding_tree  # noqa: E402

# TPU v5e per-chip constants (targets; DESIGN.md §6)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every tensor shape in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-collective-type result bytes (async ops counted at -start)."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(\([^)]*\)|\S+)\s+([\w-]+)", rhs)
        if not m:
            continue
        opname = m.group(2)
        for c in _COLLECTIVES:
            if opname == c or opname == c + "-start":
                out[c] += _shape_bytes(m.group(1))
                out["count"] += 1
                break
    return out


def run_cell(arch: str, shape: str, mesh, *, want_text: bool = False
             ) -> dict:
    mod = get(arch)
    prog = mod.cell(shape, mesh)
    in_sh = named_sharding_tree(mesh, prog.in_specs)
    out_sh = named_sharding_tree(mesh, prog.out_specs) \
        if prog.out_specs is not None else None

    t0 = time.time()
    jitted = jax.jit(prog.fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=prog.donate)
    lowered = jitted.lower(*prog.inputs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    n_chips = int(np.prod(list(mesh.shape.values())))
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        # older jaxlib returns [per-program dict]; newer returns the dict
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:   # backend may not support it
        mem_d = {"error": str(e)}

    hlo = compiled.as_text()
    # loop-aware per-device analysis: while bodies scaled by trip count
    # (xla cost_analysis counts them ONCE — useless for scan-based models)
    la = hloanalysis.analyze(hlo)
    flops = la["dot_flops"]
    bytes_acc = la["traffic_bytes"]
    coll_total = la["collective_bytes"]

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_total / ICI_BW
    dominant = max(
        [("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)], key=lambda kv: kv[1])[0]

    model_flops = prog.model_flops_per_step
    res = {
        "arch": arch, "shape": shape, "kind": prog.kind,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_total,
        "collectives": la["collectives"],
        "xla_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "memory_analysis": mem_d,
        "roofline": {
            "compute_s": t_compute,
            "memory_s": t_memory,
            "collective_s": t_coll,
            "dominant": dominant,
        },
        "model_flops_per_step": model_flops,
        "useful_flops_ratio": (
            model_flops / (flops * n_chips)
            if model_flops and flops else None),
    }
    if want_text:
        res["hlo_size"] = len(hlo)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cell", type=str, default=None,
                    help="arch:shape")
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    if args.report:
        _report(args.out)
        return

    meshes = []
    if args.both_meshes:
        meshes = [False, True]
    else:
        meshes = [args.multi_pod]

    cells = []
    if args.cell:
        a, s = args.cell.split(":")
        cells = [(a, s)]
    elif args.arch:
        cells = [(args.arch, s) for s in get(args.arch).shapes()]
    elif args.all:
        for a in ARCH_IDS:
            for s in get(a).shapes():
                cells.append((a, s))
    else:
        ap.error("need --all, --arch or --cell")

    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "2x16x16" if multi_pod else "16x16"
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for a, s in cells:
            path = os.path.join(outdir, f"{a}__{s}.json")
            if args.skip_done and os.path.exists(path):
                print(f"[skip] {mesh_name} {a}:{s}")
                continue
            print(f"[cell] {mesh_name} {a}:{s} ...", flush=True)
            try:
                res = run_cell(a, s, mesh)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                r = res["roofline"]
                print(f"       ok compile={res['compile_s']}s "
                      f"compute={r['compute_s']:.2e}s "
                      f"memory={r['memory_s']:.2e}s "
                      f"coll={r['collective_s']:.2e}s "
                      f"dom={r['dominant']}", flush=True)
            except Exception as e:
                with open(path + ".err", "w") as f:
                    f.write("".join(traceback.format_exception(e)))
                print(f"       FAIL {type(e).__name__}: {e}", flush=True)


def _report(outdir: str):
    rows = []
    for mesh_name in sorted(os.listdir(outdir)):
        d = os.path.join(outdir, mesh_name)
        if not os.path.isdir(d):
            continue
        for fn in sorted(os.listdir(d)):
            if not fn.endswith(".json"):
                continue
            with open(os.path.join(d, fn)) as f:
                rows.append(json.load(f))
    hdr = (f"{'arch':24s} {'shape':14s} {'mesh':8s} {'compute':>10s} "
           f"{'memory':>10s} {'collective':>10s} {'dom':>10s} "
           f"{'useful%':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        rf = r["roofline"]
        u = r.get("useful_flops_ratio")
        print(f"{r['arch']:24s} {r['shape']:14s} {r['mesh']:8s} "
              f"{rf['compute_s']:10.3e} {rf['memory_s']:10.3e} "
              f"{rf['collective_s']:10.3e} {rf['dominant']:>10s} "
              f"{100 * u if u else 0:8.1f}")


if __name__ == "__main__":
    main()
