"""Production mesh builders.

IMPORTANT: functions, not module-level constants — importing this module
never touches jax device state (smoke tests must keep seeing 1 CPU).
"""

from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one 256-chip v5e pod) or 2x16x16 (two pods, 512 chips).

    The single-pod mesh uses the first 256 of however many devices exist
    (the dry-run forces 512 host devices); multi-pod uses all 512.
    """
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (1..8 host devices)."""
    import jax

    devices = np.asarray(jax.devices()[:data * model]).reshape(data, model)
    return jax.sharding.Mesh(devices, ("data", "model"))
