"""Gradient compression for the cross-pod all-reduce (DESIGN.md §5).

bf16 cast before the (slow, cross-pod) gradient reduction with an
error-feedback residual kept in f32 alongside the optimizer state —
halves cross-pod collective bytes at negligible quality cost; the
residual makes the compression unbiased over time (EF-SGD style).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, residual):
    """(compressed bf16 grads, new residual).  Call BEFORE the cross-pod
    psum; the residual carries the rounding error to the next step."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        gc = gf.astype(jnp.bfloat16)
        return gc, gf - gc.astype(jnp.float32)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return treedef.unflatten([o[0] for o in out]), \
        treedef.unflatten([o[1] for o in out])


def decompress(grads):
    return jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32), grads)
