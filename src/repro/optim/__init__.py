from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, adamw_init, adamw_update, cosine_schedule,
    clip_by_global_norm)
from repro.optim import compression  # noqa: F401
