"""AdamW + schedules + clipping, pure pytree ops (no optax dependency).

Optimizer moments inherit the PARAM sharding specs (ZeRO: FSDP-sharded
over "data" wherever the params are), so opt-state memory scales down
with the mesh like the params do.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def cosine_schedule(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mh = m2 / b1c
        vh = v2 / b2c
        pf = p.astype(jnp.float32)
        pnew = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * pf)
        return pnew.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
