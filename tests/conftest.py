"""Make sibling helper modules (_hypothesis_shim) importable regardless
of pytest import mode / rootdir layout."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
