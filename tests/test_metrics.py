"""Metric registry: metric axioms + four-point property screens."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import embeddings, metrics

EMBEDDABLE = ["euclidean", "cosine", "jsd", "triangular", "sqrt_manhattan"]
NON_EMBEDDABLE = ["manhattan", "chebyshev", "angular"]
PROPER_METRICS = EMBEDDABLE + NON_EMBEDDABLE


def _sample(seed, n, d, metric):
    rng = np.random.default_rng(seed)
    x = rng.random((n, d)).astype(np.float32) + 1e-3
    return np.asarray(metrics.normalise_for(metrics.get(metric), x))


@pytest.mark.parametrize("name", PROPER_METRICS)
def test_metric_axioms(name):
    m = metrics.get(name)
    x = _sample(0, 24, 6, name)
    d = np.asarray(m.pairwise(x, x))
    assert np.allclose(np.diag(d), 0.0, atol=5e-3), "identity"
    assert np.allclose(d, d.T, atol=1e-5), "symmetry"
    assert (d >= -1e-6).all(), "positivity"
    # triangle inequality over all triples
    lhs = d[:, None, :]                      # d(a,c)
    rhs = d[:, :, None] + d[None, :, :]      # d(a,b)+d(b,c)
    assert (lhs <= rhs + 1e-4).all(), "triangle inequality"


@pytest.mark.parametrize("name", EMBEDDABLE)
def test_four_point_property_holds(name):
    m = metrics.get(name)
    x = _sample(1, 64, 8, name)
    frac, worst = embeddings.screen_metric(
        m, jnp.asarray(x), 300, jax.random.PRNGKey(0))
    assert float(frac) == 1.0, f"worst defect {worst}"


def test_four_point_property_fails_for_known_counterexamples():
    # star graph / Hamming-cycle squared-distance matrices (paper §5.7)
    star = np.array([[0, 2, 2, 1], [2, 0, 2, 1], [2, 2, 0, 1],
                     [1, 1, 1, 0]], np.float64) ** 2
    cyc = np.array([[0, 1, 2, 1], [1, 0, 1, 2], [2, 1, 0, 1],
                    [1, 2, 1, 0]], np.float64) ** 2
    assert not bool(embeddings.is_four_embeddable_quadruple(
        jnp.asarray(star)))
    assert not bool(embeddings.is_four_embeddable_quadruple(
        jnp.asarray(cyc)))


def test_chebyshev_screen_detects_failure():
    m = metrics.get("chebyshev")
    x = _sample(2, 128, 6, "chebyshev")
    frac, worst = embeddings.screen_metric(
        m, jnp.asarray(x), 500, jax.random.PRNGKey(1))
    assert float(frac) < 1.0
    assert float(worst) > 1e-5


def test_cosine_is_normalised_euclidean():
    # d_cos(v, w) = (1/sqrt(2)) ||v/|v| - w/|w|||  (paper §5.5)
    rng = np.random.default_rng(3)
    v = rng.random((10, 5)).astype(np.float32)
    w = rng.random((12, 5)).astype(np.float32)
    m = metrics.get("cosine")
    d = np.asarray(m.pairwise(v, w))
    vn = v / np.linalg.norm(v, axis=-1, keepdims=True)
    wn = w / np.linalg.norm(w, axis=-1, keepdims=True)
    eu = np.asarray(metrics.get("euclidean").pairwise(vn, wn))
    assert np.allclose(d, eu / np.sqrt(2), atol=1e-5)


def test_jsd_bounds_and_selfidentity():
    x = _sample(4, 16, 10, "jsd")
    m = metrics.get("jsd")
    d = np.asarray(m.pairwise(x, x))
    assert (d <= 1.0 + 1e-6).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.integers(0, 10000))
def test_embed_quadruple_reconstructs_euclidean(dim, seed):
    """Property: classical-MDS embedding of any Euclidean quadruple
    reproduces its distance matrix (constructive 4-embeddability)."""
    rng = np.random.default_rng(seed)
    pts = rng.random((4, dim))
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    coords = np.asarray(embeddings.embed_quadruple_l2(jnp.asarray(d2)))
    d2r = ((coords[:, None, :] - coords[None, :, :]) ** 2).sum(-1)
    assert np.allclose(d2, d2r, atol=1e-4 * max(1.0, d2.max()))


def test_hilbert_requires_four_point_flag():
    from repro.core import exclusion
    with pytest.raises(ValueError):
        exclusion.margin_fn_for(metrics.get("manhattan"), "hilbert")
    # but sqrt transform is fine
    exclusion.margin_fn_for(metrics.get("sqrt_manhattan"), "hilbert")
