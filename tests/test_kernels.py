"""Pallas kernels vs pure-jnp oracles: shape/dtype sweep + hypothesis
property tests (interpret mode on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.kernels import ops, ref

SHAPES = [(1, 1, 3), (7, 13, 5), (64, 64, 32), (70, 130, 50),
          (128, 128, 128), (129, 257, 130), (33, 200, 257)]


def _data(seed, q, n, d, simplex=False, dtype=np.float32):
    rng = np.random.default_rng(seed)
    a = rng.random((q, d)).astype(dtype) + 1e-4
    b = rng.random((n, d)).astype(dtype) + 1e-4
    if simplex:
        a = a / a.sum(-1, keepdims=True)
        b = b / b.sum(-1, keepdims=True)
    return a, b


@pytest.mark.parametrize("q,n,d", SHAPES)
@pytest.mark.parametrize("metric,simplex,tol", [
    ("euclidean", False, 1e-4), ("sqeuclidean", False, 1e-4),
    ("cosine", False, 1e-4), ("jsd", True, 1e-4),
    ("triangular", True, 1e-4)])
def test_pairwise_shapes(q, n, d, metric, simplex, tol):
    a, b = _data(0, q, n, d, simplex)
    out = ops.pairwise_distance(a, b, metric)
    exp = {
        "euclidean": ref.pairwise_l2_ref,
        "sqeuclidean": lambda x, y: ref.pairwise_l2_ref(x, y, squared=True),
        "cosine": ref.pairwise_cosine_ref,
        "jsd": ref.pairwise_jsd_ref,
        "triangular": ref.pairwise_triangular_ref,
    }[metric](jnp.asarray(a), jnp.asarray(b))
    assert out.shape == (q, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_pairwise_dtypes(dtype):
    a, b = _data(1, 40, 60, 33, dtype=dtype)
    out = ops.pairwise_distance(a, b, "euclidean")
    exp = ref.pairwise_l2_ref(jnp.asarray(a, jnp.float32),
                              jnp.asarray(b, jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=5e-3, rtol=5e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 70), st.integers(1, 70), st.integers(1, 40),
       st.integers(0, 10**6))
def test_pairwise_l2_property(q, n, d, seed):
    a, b = _data(seed, q, n, d)
    out = np.asarray(ops.pairwise_distance(a, b, "euclidean"))
    exp = np.asarray(ref.pairwise_l2_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(out, exp, atol=2e-4, rtol=2e-4)


def test_exclusion_margins_kernel():
    rng = np.random.default_rng(0)
    q = rng.random((70, 50)).astype(np.float32)
    p1 = rng.random((37, 50)).astype(np.float32)
    p2 = rng.random((37, 50)).astype(np.float32)
    d12 = np.asarray(ref.pairwise_l2_ref(
        jnp.asarray(p1), jnp.asarray(p2))).diagonal().copy()
    hyp, hil = ops.exclusion_margins(q, p1, p2, d12)
    rh, ri = ref.exclusion_margins_ref(
        jnp.asarray(q), jnp.asarray(p1), jnp.asarray(p2),
        jnp.asarray(d12))
    np.testing.assert_allclose(np.asarray(hyp), np.asarray(rh), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hil), np.asarray(ri), atol=1e-4)
    # weakness: hilbert margin >= hyperbolic wherever d1 >= d2
    mask = np.asarray(rh) >= 0
    assert (np.asarray(hil)[mask] >= np.asarray(hyp)[mask] - 1e-5).all()


GATHER_SHAPES = [(1, 1, 3), (9, 37, 10), (8, 128, 16), (17, 260, 130)]


@pytest.mark.parametrize("q,l,d", GATHER_SHAPES)
@pytest.mark.parametrize("metric,simplex,tol", [
    ("euclidean", False, 1e-5), ("sqeuclidean", False, 1e-5),
    ("cosine", False, 1e-5), ("jsd", True, 1e-5),
    ("triangular", True, 1e-5)])
def test_gather_block_shapes(q, l, d, metric, simplex, tol):
    """Gather-block kernels (frontier-traversal shape) vs the jnp path,
    with and without the squared-norm cache."""
    from repro.core.blockdist import block_distance
    rng = np.random.default_rng(3)
    qa = rng.random((q, d)).astype(np.float32) + 1e-4
    pts = rng.random((q, l, d)).astype(np.float32) + 1e-4
    if simplex:
        qa = qa / qa.sum(-1, keepdims=True)
        pts = pts / pts.sum(-1, keepdims=True)
    ref = block_distance(metric, jnp.asarray(qa), jnp.asarray(pts),
                         impl="jnp")
    out = block_distance(metric, jnp.asarray(qa), jnp.asarray(pts),
                         impl="pallas")
    assert out.shape == (q, l)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=tol, rtol=tol)
    nsq = jnp.sum(jnp.asarray(pts) ** 2, -1)
    out_cached = block_distance(metric, jnp.asarray(qa), jnp.asarray(pts),
                                pts_norm_sq=nsq, impl="pallas")
    np.testing.assert_allclose(np.asarray(out_cached), np.asarray(ref),
                               atol=tol, rtol=tol)


def test_gather_block_norm_cache_jnp_path():
    """The jnp path must honour the cache too (traversal passes gathered
    tree norms): cached and on-the-fly results agree exactly."""
    from repro.core.blockdist import block_distance
    rng = np.random.default_rng(4)
    qa = jnp.asarray(rng.random((5, 12)).astype(np.float32))
    pts = jnp.asarray(rng.random((5, 20, 12)).astype(np.float32))
    nsq = jnp.sum(pts * pts, -1)
    for metric in ("euclidean", "cosine"):
        a = block_distance(metric, qa, pts, impl="jnp")
        b = block_distance(metric, qa, pts, pts_norm_sq=nsq, impl="jnp")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_exclusion_kernel_degenerate_pairs():
    """d12 == 0 pairs must yield hilbert margin 0 (no exclusion)."""
    rng = np.random.default_rng(1)
    q = rng.random((8, 16)).astype(np.float32)
    p = rng.random((4, 16)).astype(np.float32)
    hyp, hil = ops.exclusion_margins(q, p, p, np.zeros(4, np.float32))
    assert np.allclose(np.asarray(hil), 0.0, atol=1e-6)
    assert np.allclose(np.asarray(hyp), 0.0, atol=1e-6)
