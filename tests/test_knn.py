"""Exact k-NN: both tree engines (and every frontier width) must return
ids and distances identical to the ``bruteforce.knn`` oracle — including
ties at the k-boundary (broken by (distance, id)) and k > n padding —
while Hilbert never costs more distance evaluations than Hyperbolic.

Also the silent-truncation regression tests: an exhausted iteration
budget must set ``iter_overflow`` (never return a truncated set without
a flag), and ``check_complete`` must refuse it.
"""

import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import bruteforce
from repro.core.tree import (build_disat, build_ght, build_mht,
                             check_complete, knn_search_binary_tree,
                             knn_search_sat, search_binary_tree,
                             search_sat)

CASES = [
    ("euclidean", False),
    ("cosine", False),
    ("jsd", True),
    ("triangular", True),
]


def _data(simplex, n=700, d=8, nq=16, seed=0):
    rng = np.random.default_rng(seed)
    raw = rng.random((n + nq, d)).astype(np.float32)
    if simplex:
        raw = raw / raw.sum(-1, keepdims=True)
    return raw[:n], raw[n:]


def _bf(data, queries, metric, k):
    d, i = bruteforce.knn(np.asarray(data), np.asarray(queries),
                          metric_name=metric, k=k)
    return np.asarray(d), np.asarray(i)


def _assert_exact(st, bf_d, bf_i, ctx=""):
    assert not np.asarray(st.stack_overflow).any(), ctx
    assert not np.asarray(st.iter_overflow).any(), ctx
    np.testing.assert_array_equal(np.asarray(st.ids), bf_i,
                                  err_msg=f"{ctx}: ids")
    np.testing.assert_allclose(np.asarray(st.dists), bf_d,
                               atol=1e-5, rtol=1e-5, err_msg=f"{ctx}: d")


@pytest.mark.parametrize("metric,simplex", CASES)
@pytest.mark.parametrize("kind", ["ght", "mht"])
def test_binary_knn_exact(metric, simplex, kind):
    data, queries = _data(simplex)
    bf_d, bf_i = _bf(data, queries, metric, 10)
    build = {"ght": build_ght, "mht": build_mht}[kind]
    tree = build(data, metric, leaf_size=16, seed=1)
    nd = {}
    for mech in ("hyperbolic", "hilbert"):
        for b in (1, 4, 8):
            st = knn_search_binary_tree(tree, queries, 10,
                                        metric_name=metric,
                                        mechanism=mech, frontier=b)
            _assert_exact(st, bf_d, bf_i, f"{kind}/{metric}/{mech}/B={b}")
            if b == 1:
                nd[mech] = np.asarray(st.n_dist)
    # per-query at B=1: hilbert never MORE distance evals
    assert nd["hilbert"].sum() <= nd["hyperbolic"].sum()


@pytest.mark.parametrize("metric,simplex", CASES)
def test_disat_knn_exact(metric, simplex):
    data, queries = _data(simplex, n=600)
    bf_d, bf_i = _bf(data, queries, metric, 10)
    tree = build_disat(data, metric, seed=2)
    nd = {}
    for mech in ("hyperbolic", "hilbert"):
        for b in (1, 4, 8):
            st = knn_search_sat(tree, queries, 10, metric_name=metric,
                                mechanism=mech, frontier=b)
            _assert_exact(st, bf_d, bf_i, f"disat/{metric}/{mech}/B={b}")
            if b == 1:
                nd[mech] = np.asarray(st.n_dist)
    assert nd["hilbert"].sum() <= nd["hyperbolic"].sum()


def test_knn_ties_at_k_boundary():
    """Duplicated points straddling the k-boundary: the k-set must match
    brute force exactly (ties broken toward smaller ids, lax.top_k's
    rule)."""
    rng = np.random.default_rng(5)
    base = rng.random((40, 6)).astype(np.float32)
    data = np.repeat(base, 4, axis=0)         # ids 4j..4j+3 coincide
    queries = rng.random((7, 6)).astype(np.float32)
    for k in (3, 6, 10):                      # cuts inside a tied group
        bf_d, bf_i = _bf(data, queries, "euclidean", k)
        for build, search in [(build_ght, knn_search_binary_tree),
                              (build_mht, knn_search_binary_tree)]:
            tree = build(data, "euclidean", leaf_size=8, seed=3)
            for mech in ("hyperbolic", "hilbert"):
                st = search(tree, queries, k, metric_name="euclidean",
                            mechanism=mech)
                _assert_exact(st, bf_d, bf_i, f"ties k={k} {mech}")
        sat = build_disat(data, "euclidean", seed=3)
        for mech in ("hyperbolic", "hilbert"):
            st = knn_search_sat(sat, queries, k, metric_name="euclidean",
                                mechanism=mech)
            _assert_exact(st, bf_d, bf_i, f"ties sat k={k} {mech}")


def test_knn_k_exceeds_n():
    """k > n: all n points returned in (distance, id) order, the rest
    padded with (-1, +inf) — identically in oracle and engines."""
    data, queries = _data(False, n=20, nq=5)
    for k in (20, 32):
        bf_d, bf_i = _bf(data, queries, "euclidean", k)
        if k > 20:
            assert (bf_i[:, 20:] == -1).all()
            assert np.isinf(bf_d[:, 20:]).all()
        tree = build_mht(data, "euclidean", leaf_size=4, seed=1)
        st = knn_search_binary_tree(tree, queries, k,
                                    metric_name="euclidean")
        _assert_exact(st, bf_d, bf_i, f"k={k}>n")
        sat = build_disat(data, "euclidean", seed=1)
        st = knn_search_sat(sat, queries, k, metric_name="euclidean")
        _assert_exact(st, bf_d, bf_i, f"sat k={k}>n")


def test_knn_k1_and_unsound_mechanism():
    data, queries = _data(False, n=300)
    bf_d, bf_i = _bf(data, queries, "euclidean", 1)
    tree = build_ght(data, "euclidean", leaf_size=16, seed=1)
    st = knn_search_binary_tree(tree, queries, 1, metric_name="euclidean")
    _assert_exact(st, bf_d, bf_i, "k=1")
    with pytest.raises(ValueError):
        knn_search_binary_tree(tree, queries, 0, metric_name="euclidean")
    mt = build_ght(data, "manhattan", leaf_size=16, seed=1)
    with pytest.raises(ValueError):
        knn_search_binary_tree(mt, queries, 3, metric_name="manhattan",
                               mechanism="hilbert")
    # hyperbolic is sound for any metric
    bf_d, bf_i = _bf(data, queries, "manhattan", 3)
    st = knn_search_binary_tree(mt, queries, 3, metric_name="manhattan",
                                mechanism="hyperbolic")
    _assert_exact(st, bf_d, bf_i, "manhattan hyperbolic")


@settings(max_examples=8, deadline=None)
@given(st.integers(40, 300), st.integers(1, 24), st.integers(0, 10**6))
def test_knn_property(n, k, seed):
    """Random (n, k, seed): MHT k-NN == brute force, ids and distances."""
    rng = np.random.default_rng(seed)
    raw = rng.random((n + 4, 6)).astype(np.float32)
    data, queries = raw[:n], raw[n:]
    bf_d, bf_i = _bf(data, queries, "euclidean", k)
    tree = build_mht(data, "euclidean", leaf_size=8, seed=seed % 97)
    st = knn_search_binary_tree(tree, queries, k, metric_name="euclidean",
                                frontier=4)
    _assert_exact(st, bf_d, bf_i, f"property n={n} k={k} seed={seed}")


# ---------------------------------------------------------------------------
# silent-truncation regression (bugfix): iteration budget exhaustion must
# be flagged, and callers must refuse the truncated results
# ---------------------------------------------------------------------------

def test_range_iter_overflow_flagged():
    """Before the fix, _search_binary/_search_sat exited silently at
    max_iter with non-empty stacks; now every truncated lane flags
    iter_overflow and check_complete refuses the stats."""
    data, queries = _data(False, n=900)
    tree = build_mht(data, "euclidean", leaf_size=16, seed=1)
    st = search_binary_tree(tree, queries, 0.4, metric_name="euclidean",
                            frontier=1, max_iter=2)
    assert np.asarray(st.iter_overflow).any()
    with pytest.raises(RuntimeError, match="truncated"):
        check_complete(st)
    sat = build_disat(data, "euclidean", seed=2)
    st = search_sat(sat, queries, 0.4, metric_name="euclidean",
                    frontier=1, max_iter=2)
    assert np.asarray(st.iter_overflow).any()
    with pytest.raises(RuntimeError, match="truncated"):
        check_complete(st)


def test_knn_iter_overflow_flagged():
    data, queries = _data(False, n=900)
    tree = build_mht(data, "euclidean", leaf_size=16, seed=1)
    st = knn_search_binary_tree(tree, queries, 5, metric_name="euclidean",
                                frontier=1, max_iter=2)
    assert np.asarray(st.iter_overflow).any()
    with pytest.raises(RuntimeError, match="truncated"):
        check_complete(st)
    sat = build_disat(data, "euclidean", seed=2)
    st = knn_search_sat(sat, queries, 5, metric_name="euclidean",
                        frontier=1, max_iter=2)
    assert np.asarray(st.iter_overflow).any()
    with pytest.raises(RuntimeError, match="truncated"):
        check_complete(st)


def test_iter_overflow_clear_on_complete_runs():
    """The default budget (n_nodes + 8) provably suffices: the flag must
    stay clear on every normal search."""
    data, queries = _data(False, n=500)
    tree = build_mht(data, "euclidean", leaf_size=16, seed=1)
    st = search_binary_tree(tree, queries, 0.3, metric_name="euclidean")
    assert not np.asarray(st.iter_overflow).any()
    st = knn_search_binary_tree(tree, queries, 5, metric_name="euclidean")
    assert not np.asarray(st.iter_overflow).any()
    check_complete(st)
