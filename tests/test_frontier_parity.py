"""Frontier-batched traversal parity (DESIGN.md §3).

Exclusion decisions are purely local geometry, so the visited-node set is
independent of pop order and frontier width: for EVERY metric x mechanism
x engine, a width-B frontier must return byte-identical result sets and
per-query ``n_dist`` to the single-pop engine (B=1), with strictly fewer
loop iterations and no stack overflow at the documented caps.
"""

import numpy as np
import pytest

from repro.core.tree import (build_disat, build_ght, build_mht,
                             search_binary_tree, search_sat)

CASES = [
    ("euclidean", 0.32, False),
    ("cosine", 0.18, False),
    ("jsd", 0.09, True),
    ("triangular", 0.12, True),
]

MECHS_FOR = {
    "euclidean": ("hyperbolic", "hilbert"),
    "cosine": ("hyperbolic", "hilbert"),
    "jsd": ("hyperbolic", "hilbert"),
    "triangular": ("hyperbolic", "hilbert"),
}


def _data(simplex, n=700, d=8, nq=16, seed=0):
    rng = np.random.default_rng(seed)
    raw = rng.random((n + nq, d)).astype(np.float32)
    if simplex:
        raw = raw / raw.sum(-1, keepdims=True)
    return raw[:n], raw[n:]


def _assert_parity(st1, stb, b):
    assert not np.asarray(st1.stack_overflow).any(), "B=1 stack overflow"
    assert not np.asarray(stb.stack_overflow).any(), f"B={b} stack overflow"
    assert not np.asarray(st1.overflow).any()
    assert not np.asarray(stb.overflow).any()
    assert stb.result_sets() == st1.result_sets(), f"B={b} result sets"
    np.testing.assert_array_equal(
        np.asarray(stb.n_dist), np.asarray(st1.n_dist),
        err_msg=f"B={b} n_dist")
    assert int(stb.iters) < int(st1.iters)


@pytest.mark.parametrize("metric,t,simplex", CASES)
@pytest.mark.parametrize("kind", ["ght", "mht"])
def test_binary_frontier_parity(metric, t, simplex, kind):
    data, queries = _data(simplex)
    build = {"ght": build_ght, "mht": build_mht}[kind]
    tree = build(data, metric, leaf_size=16, seed=1)
    for mech in MECHS_FOR[metric]:
        st1 = search_binary_tree(tree, queries, t, metric_name=metric,
                                 mechanism=mech, frontier=1)
        st8 = search_binary_tree(tree, queries, t, metric_name=metric,
                                 mechanism=mech, frontier=8)
        _assert_parity(st1, st8, 8)


@pytest.mark.parametrize("metric,t,simplex", CASES)
def test_sat_frontier_parity(metric, t, simplex):
    data, queries = _data(simplex, n=600)
    tree = build_disat(data, metric, seed=2)
    for mech in MECHS_FOR[metric]:
        st1 = search_sat(tree, queries, t, metric_name=metric,
                         mechanism=mech, frontier=1)
        st8 = search_sat(tree, queries, t, metric_name=metric,
                         mechanism=mech, frontier=8)
        _assert_parity(st1, st8, 8)


def test_frontier_width_sweep():
    """B in {1, 4, 8, 16}: identical outcomes, monotone-ish iteration
    drop, iters lower-bounded by pops/B."""
    data, queries = _data(False, n=900)
    tree = build_ght(data, "euclidean", leaf_size=16, seed=3)
    base = search_binary_tree(tree, queries, 0.32,
                              metric_name="euclidean", frontier=1)
    prev_iters = int(base.iters)
    for b in (4, 8, 16):
        st = search_binary_tree(tree, queries, 0.32,
                                metric_name="euclidean", frontier=b)
        _assert_parity(base, st, b)
        assert int(st.iters) <= prev_iters
        prev_iters = int(st.iters)
    assert int(st.iters) * 4 <= int(base.iters), \
        "B=16 should cut trip count >= 4x on this workload"


def test_frontier_rejects_bad_width():
    data, queries = _data(False, n=100)
    tree = build_ght(data, "euclidean", leaf_size=16, seed=1)
    with pytest.raises(ValueError):
        search_binary_tree(tree, queries, 0.3, metric_name="euclidean",
                           frontier=0)
    sat = build_disat(data, "euclidean", seed=1)
    with pytest.raises(ValueError):
        search_sat(sat, queries, 0.3, metric_name="euclidean", frontier=-1)


def test_frontier_degenerate_data():
    """Ball-fallback nodes (duplicates + collinear points) stay exact
    under frontier batching."""
    rng = np.random.default_rng(0)
    data = np.concatenate([
        np.zeros((30, 4)), np.ones((30, 4)),
        np.linspace(0, 1, 60)[:, None] * np.ones((1, 4)),
    ]).astype(np.float32)
    queries = rng.random((6, 4)).astype(np.float32)
    tree = build_mht(data, "euclidean", leaf_size=8, seed=3)
    st1 = search_binary_tree(tree, queries, 0.6, metric_name="euclidean",
                             r_cap=256, frontier=1)
    st8 = search_binary_tree(tree, queries, 0.6, metric_name="euclidean",
                             r_cap=256, frontier=8)
    _assert_parity(st1, st8, 8)
