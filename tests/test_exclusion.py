"""Exclusion predicates: soundness, relative weakness (paper Appendix A),
and geometric identity of the Hilbert margin."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import exclusion as E


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 10**6))
def test_hilbert_weaker_than_hyperbolic(seed):
    """Appendix A: hilbert margin >= hyperbolic margin whenever the three
    points satisfy triangle inequality => any hyperbolic exclusion is
    also a hilbert exclusion (never the reverse)."""
    rng = np.random.default_rng(seed)
    q, p1, p2 = rng.normal(size=(3, 6))
    d1 = np.linalg.norm(q - p1)
    d2 = np.linalg.norm(q - p2)
    d12 = np.linalg.norm(p1 - p2)
    m_hyp = float(E.hyperbolic_margin(d1, d2, d12))
    m_hil = float(E.hilbert_margin(d1, d2, d12))
    if d1 >= d2:
        assert m_hil >= m_hyp - 1e-9
    else:
        assert m_hil <= m_hyp + 1e-9


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 10**6))
def test_hilbert_margin_is_hyperplane_distance(seed):
    """Theorem 1: (d1^2-d2^2)/(2 d12) == signed distance from q to the
    bisector hyperplane, exactly, in Euclidean space."""
    rng = np.random.default_rng(seed)
    q, p1, p2 = rng.normal(size=(3, 5))
    if np.linalg.norm(p1 - p2) < 1e-3:
        return
    d1 = np.linalg.norm(q - p1)
    d2 = np.linalg.norm(q - p2)
    d12 = np.linalg.norm(p1 - p2)
    m_hil = float(E.hilbert_margin(d1, d2, d12))
    mid = (p1 + p2) / 2
    normal = (p2 - p1) / d12
    signed = float((q - mid) @ normal)    # + => q on the p2 side
    assert abs(m_hil - signed) < 1e-6 * max(1.0, abs(signed))


@settings(max_examples=300, deadline=None)
@given(st.integers(0, 10**6), st.floats(0.01, 1.0))
def test_exclusion_soundness_euclidean(seed, t):
    """If the hilbert condition fires for (q, p1, p2, t) then NO point
    within t of q is closer to p1 (Theorems 1+2), verified by sampling
    the ball."""
    rng = np.random.default_rng(seed)
    q, p1, p2 = rng.normal(size=(3, 4))
    d1 = np.linalg.norm(q - p1)
    d2 = np.linalg.norm(q - p2)
    d12 = np.linalg.norm(p1 - p2)
    if not bool(E.exclude_p1_side_hilbert(d1, d2, d12, t)):
        return
    # sample points in the ball B(q, t)
    u = rng.normal(size=(64, 4))
    u = u / np.linalg.norm(u, axis=-1, keepdims=True)
    r = t * rng.random((64, 1)) ** 0.25
    s = q + u * r
    ds1 = np.linalg.norm(s - p1, axis=-1)
    ds2 = np.linalg.norm(s - p2, axis=-1)
    assert (ds1 > ds2 - 1e-9).all()


def test_degenerate_pivots_never_exclude():
    m = E.hilbert_margin(jnp.asarray(1.0), jnp.asarray(0.2),
                         jnp.asarray(0.0))
    assert float(m) == 0.0
    left, right = E.partition_exclusions(
        jnp.asarray(1.0), jnp.asarray(0.2), jnp.asarray(0.0),
        jnp.asarray(0.1), use_hilbert=True)
    assert not bool(left) and not bool(right)


def test_at_most_one_side_excluded():
    rng = np.random.default_rng(0)
    d1 = rng.random(100) * 2
    d2 = rng.random(100) * 2
    d12 = rng.random(100) + 0.5
    for mech in (True, False):
        l, r = E.partition_exclusions(
            jnp.asarray(d1), jnp.asarray(d2), jnp.asarray(d12),
            jnp.asarray(0.1), use_hilbert=mech)
        assert not bool(jnp.any(l & r))


def test_ball_exclusions():
    assert bool(E.exclude_outside_ball(jnp.asarray(2.0), jnp.asarray(1.0),
                                       jnp.asarray(0.5)))
    assert not bool(E.exclude_outside_ball(
        jnp.asarray(1.4), jnp.asarray(1.0), jnp.asarray(0.5)))
    assert bool(E.exclude_inside_ring(jnp.asarray(0.2), jnp.asarray(1.0),
                                      jnp.asarray(0.5)))
