"""Drop-in fallback for `hypothesis` so property-test modules collect
and run everywhere.

When hypothesis is installed (CI — see requirements-dev.txt) this module
re-exports the real `given` / `settings` / `strategies`.  When it is
missing (minimal containers), a deterministic sampling shim runs each
property with `max_examples` seeded draws — weaker than hypothesis (no
shrinking, no adaptive search) but it keeps every property exercised
instead of skipping the whole module.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as _np

    class _Strategy:
        def __init__(self, sampler):
            self._sampler = sampler

        def sample(self, rng):
            return self._sampler(rng)

    class _StrategiesShim:
        @staticmethod
        def integers(min_value, max_value):
            # hypothesis bounds are inclusive
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    strategies = _StrategiesShim()

    def settings(max_examples: int = 100, **_):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # Zero-arg wrapper WITHOUT functools.wraps: pytest must not
            # follow __wrapped__ and mistake strategy args for fixtures.
            def wrapper():
                # @settings may sit above @given (stamps this wrapper) or
                # below it (stamps fn) — both orders are valid hypothesis
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 100))
                for ex in range(n):
                    rng = _np.random.default_rng(0xC0FFEE + 7919 * ex)
                    fn(*[s.sample(rng) for s in strats])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
