"""System behaviour: every (index x mechanism x metric) returns EXACTLY
the brute-force result set (paper §6.5) and Hilbert never does more
distance evaluations than Hyperbolic."""

import numpy as np
import pytest

from repro.core import bruteforce
from repro.core.tree import (build_disat, build_ght, build_mht,
                             search_binary_tree, search_sat)

CASES = [
    ("euclidean", 0.32, False),
    ("cosine", 0.18, False),
    ("jsd", 0.09, True),
    ("triangular", 0.12, True),
]


def _data(metric_simplex, n=1500, d=8, nq=25, seed=0):
    rng = np.random.default_rng(seed)
    raw = rng.random((n + nq, d)).astype(np.float32)
    if metric_simplex:
        raw = raw / raw.sum(-1, keepdims=True)
    return raw[:n], raw[n:]


@pytest.mark.parametrize("metric,t,simplex", CASES)
@pytest.mark.parametrize("kind", ["ght", "mht"])
def test_binary_tree_exact(metric, t, simplex, kind):
    data, queries = _data(simplex)
    _, sets_bf = bruteforce.range_search(data, queries, t,
                                         metric_name=metric)
    build = {"ght": build_ght, "mht": build_mht}[kind]
    tree = build(data, metric, leaf_size=16, seed=1)
    nd = {}
    for mech in ("hyperbolic", "hilbert"):
        st = search_binary_tree(tree, queries, t, metric_name=metric,
                                mechanism=mech)
        assert not np.asarray(st.overflow).any()
        assert not np.asarray(st.stack_overflow).any()
        assert st.result_sets() == sets_bf
        nd[mech] = np.asarray(st.n_dist)
    # per-query: hilbert never MORE distance evals (strictly weaker cond)
    assert (nd["hilbert"] <= nd["hyperbolic"]).all()
    assert nd["hilbert"].sum() < nd["hyperbolic"].sum()


@pytest.mark.parametrize("metric,t,simplex", CASES)
def test_disat_exact(metric, t, simplex):
    data, queries = _data(simplex, n=1200)
    _, sets_bf = bruteforce.range_search(data, queries, t,
                                         metric_name=metric)
    tree = build_disat(data, metric, seed=2)
    nd = {}
    for mech in ("hyperbolic", "hilbert"):
        st = search_sat(tree, queries, t, metric_name=metric,
                        mechanism=mech)
        assert not np.asarray(st.overflow).any()
        assert not np.asarray(st.stack_overflow).any()
        assert st.result_sets() == sets_bf
        nd[mech] = np.asarray(st.n_dist)
    assert nd["hilbert"].sum() < nd["hyperbolic"].sum()


def test_degenerate_data_ball_fallback():
    """Duplicates + collinear points: the ball-fallback nodes must keep
    every mechanism exact (regression: the forced-split bug)."""
    rng = np.random.default_rng(0)
    data = np.concatenate([
        np.zeros((40, 4)), np.ones((40, 4)),
        np.linspace(0, 1, 80)[:, None] * np.ones((1, 4)),
    ]).astype(np.float32)
    queries = rng.random((8, 4)).astype(np.float32)
    _, sets_bf = bruteforce.range_search(data, queries, 0.6,
                                         metric_name="euclidean")
    for build, search in [(build_ght, search_binary_tree),
                          (build_mht, search_binary_tree)]:
        tree = build(data, "euclidean", leaf_size=8, seed=3)
        for mech in ("hyperbolic", "hilbert"):
            st = search(tree, queries, 0.6, metric_name="euclidean",
                        mechanism=mech, r_cap=256)
            assert st.result_sets() == sets_bf
    sat = build_disat(data, "euclidean", seed=3)
    for mech in ("hyperbolic", "hilbert"):
        st = search_sat(sat, queries, 0.6, metric_name="euclidean",
                        mechanism=mech, r_cap=256)
        assert st.result_sets() == sets_bf


def test_mht_reuses_parent_distance():
    """MHT distance counts must be strictly below GHT's on the same data
    (pivot reuse, paper §6.3)."""
    data, queries = _data(False, n=2000)
    ght = build_ght(data, "euclidean", leaf_size=16, seed=1)
    mht = build_mht(data, "euclidean", leaf_size=16, seed=1)
    nd_g = np.asarray(search_binary_tree(
        ght, queries, 0.3, metric_name="euclidean",
        mechanism="hilbert").n_dist).mean()
    nd_m = np.asarray(search_binary_tree(
        mht, queries, 0.3, metric_name="euclidean",
        mechanism="hilbert").n_dist).mean()
    assert nd_m < nd_g


def test_unsound_mechanism_rejected():
    data, queries = _data(False, n=300)
    tree = build_ght(data, "manhattan", leaf_size=16, seed=1)
    with pytest.raises(ValueError):
        search_binary_tree(tree, queries, 0.3, metric_name="manhattan",
                           mechanism="hilbert")
    # hyperbolic is fine for any metric
    search_binary_tree(tree, queries, 0.3, metric_name="manhattan",
                       mechanism="hyperbolic")
