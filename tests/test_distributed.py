"""Distributed: forest search on a multi-device (host-platform) mesh and
the dry-run machinery on a tiny mesh — run in a subprocess so the forced
device count never leaks into other tests."""

import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_forest_search_multidevice():
    out = _run_sub("""
import numpy as np, jax, json
from repro.core.distributed import build_forest, forest_search
from repro.core import bruteforce
rng = np.random.default_rng(0)
data = rng.random((4000, 8)).astype(np.float32)
queries = rng.random((16, 8)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))
forest = build_forest(data, "euclidean", mesh, kind="mht", leaf_size=16)
gids, cnt, nd = forest_search(forest, queries, 0.35,
                              metric_name="euclidean", mechanism="hilbert")
_, sets_bf = bruteforce.range_search(data, queries, 0.35,
                                     metric_name="euclidean")
sets = [set(x for x in row.tolist() if x >= 0) for row in np.asarray(gids)]
_, _, nd_hyp = forest_search(forest, queries, 0.35,
                             metric_name="euclidean", mechanism="hyperbolic")
print(json.dumps({
    "identical": sets == sets_bf,
    "hilbert_nd": float(np.mean(np.asarray(nd))),
    "hyperbolic_nd": float(np.mean(np.asarray(nd_hyp))),
}))
""")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["identical"] is True
    assert res["hilbert_nd"] < res["hyperbolic_nd"]


@pytest.mark.slow
def test_dryrun_cell_small_mesh():
    """Lower+compile one LM train cell on a 2x2 debug mesh (same code
    path as the 512-chip dry-run, CI-sized)."""
    out = _run_sub("""
import numpy as np, jax, json
import repro.launch.dryrun as dr
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh(2, 2)
res = dr.run_cell("llama3.2-1b", "train_4k", mesh)
print(json.dumps({"dom": res["roofline"]["dominant"],
                  "flops": res["flops_per_device"] > 0}))
""", devices=4)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["flops"] is True
