"""Distributed: forest search on a multi-device (host-platform) mesh and
the dry-run machinery on a tiny mesh — run in a subprocess so the forced
device count never leaks into other tests."""

import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_forest_search_multidevice():
    out = _run_sub("""
import numpy as np, jax, json
from repro.core.distributed import build_forest, forest_search
from repro.core import bruteforce
rng = np.random.default_rng(0)
data = rng.random((4000, 8)).astype(np.float32)
queries = rng.random((16, 8)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))
forest = build_forest(data, "euclidean", mesh, kind="mht", leaf_size=16)
gids, cnt, nd = forest_search(forest, queries, 0.35,
                              metric_name="euclidean", mechanism="hilbert")
_, sets_bf = bruteforce.range_search(data, queries, 0.35,
                                     metric_name="euclidean")
sets = [set(x for x in row.tolist() if x >= 0) for row in np.asarray(gids)]
_, _, nd_hyp = forest_search(forest, queries, 0.35,
                             metric_name="euclidean", mechanism="hyperbolic")
print(json.dumps({
    "identical": sets == sets_bf,
    "hilbert_nd": float(np.mean(np.asarray(nd))),
    "hyperbolic_nd": float(np.mean(np.asarray(nd_hyp))),
}))
""")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["identical"] is True
    assert res["hilbert_nd"] < res["hyperbolic_nd"]


def test_forest_fallback_shard_no_duplicates():
    """Regression (forest duplicates): when n_shards doesn't divide n the
    empty-shard fallback used to index data[:1] with offset 0, so global
    id 0 was returned by several shards and res_cnt / n_dist were
    double-counted.  Fallback shards are now marked (id_offset == -1) and
    masked out: counts match brute force exactly and no id repeats."""
    out = _run_sub("""
import numpy as np, jax, json
from repro.core.distributed import build_forest, forest_search, forest_knn
from repro.core import bruteforce
rng = np.random.default_rng(3)
n = 9                      # 8 shards -> shards 5..7 are empty fallbacks
data = rng.random((n, 8)).astype(np.float32)
queries = np.concatenate([data[:2] + 1e-3, rng.random((6, 8))]) \
    .astype(np.float32)    # first queries sit near id 0/1: hits guaranteed
mesh = jax.make_mesh((8,), ("data",))
forest = build_forest(data, "euclidean", mesh, kind="mht", leaf_size=4)
assert int(np.asarray(forest.id_offset).min()) == -1  # fallbacks marked
t = 2.0                    # radius covers every point: worst case for dups
gids, cnt, nd = forest_search(forest, queries, t, metric_name="euclidean")
gids = np.asarray(gids)
valid = [sorted(x for x in row.tolist() if x >= 0) for row in gids]
cnt_bf, sets_bf = bruteforce.range_search(data, queries, t,
                                          metric_name="euclidean")
no_dups = all(len(v) == len(set(v)) for v in valid)
sets_ok = [set(v) for v in valid] == sets_bf
cnt_ok = np.array_equal(np.asarray(cnt), np.asarray(cnt_bf))
bf_d, bf_i = bruteforce.knn(data, queries, metric_name="euclidean", k=4)
kd, ki, knd = forest_knn(forest, queries, 4, metric_name="euclidean")
knn_ids_ok = np.array_equal(np.asarray(ki), np.asarray(bf_i))
# atol 1e-4: the first queries sit ~1e-3 from a data point, where the
# |x|^2+|y|^2-2xy expansion's cancellation noise is sqrt-amplified
knn_d_ok = bool(np.allclose(np.asarray(kd), np.asarray(bf_d), atol=1e-4))
print(json.dumps({"no_dups": no_dups, "sets_ok": sets_ok,
                  "cnt_ok": cnt_ok, "knn_ids_ok": knn_ids_ok,
                  "knn_d_ok": knn_d_ok,
                  "nd_max": int(np.asarray(nd).max())}))
""")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["no_dups"] is True, "duplicate global ids returned"
    assert res["sets_ok"] is True
    assert res["cnt_ok"] is True, "res_cnt double-counted"
    assert res["knn_ids_ok"] is True
    assert res["knn_d_ok"] is True
    # masked fallback shards contribute no distance evaluations: with 6
    # real shards of <= 2 points each, per-query cost is bounded by n
    assert res["nd_max"] <= 9


def test_forest_knn_multidevice():
    """forest_knn == bruteforce.knn (ids and distances) on a real multi-
    shard mesh, and the truncation refusal fires on a tiny max_iter."""
    out = _run_sub("""
import numpy as np, jax, json
from repro.core.distributed import build_forest, forest_knn, forest_search
from repro.core import bruteforce
rng = np.random.default_rng(0)
data = rng.random((4000, 8)).astype(np.float32)
queries = rng.random((16, 8)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))
forest = build_forest(data, "euclidean", mesh, kind="mht", leaf_size=16)
bf_d, bf_i = bruteforce.knn(data, queries, metric_name="euclidean", k=10)
d_hil, i_hil, nd_hil = forest_knn(forest, queries, 10,
                                  metric_name="euclidean",
                                  mechanism="hilbert")
d_hyp, i_hyp, nd_hyp = forest_knn(forest, queries, 10,
                                  metric_name="euclidean",
                                  mechanism="hyperbolic")
ids_ok = np.array_equal(np.asarray(i_hil), np.asarray(bf_i)) and \
    np.array_equal(np.asarray(i_hyp), np.asarray(bf_i))
d_ok = bool(np.allclose(np.asarray(d_hil), np.asarray(bf_d), atol=1e-5))
trunc_refused = False
try:
    forest_knn(forest, queries, 10, metric_name="euclidean", max_iter=2)
except RuntimeError as e:
    trunc_refused = "truncated" in str(e)
trunc_refused_range = False
try:
    forest_search(forest, queries, 0.35, metric_name="euclidean",
                  max_iter=2)
except RuntimeError as e:
    trunc_refused_range = "truncated" in str(e)
print(json.dumps({
    "ids_ok": ids_ok, "d_ok": d_ok,
    "hilbert_nd": float(np.mean(np.asarray(nd_hil))),
    "hyperbolic_nd": float(np.mean(np.asarray(nd_hyp))),
    "trunc_refused": trunc_refused,
    "trunc_refused_range": trunc_refused_range,
}))
""")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ids_ok"] is True
    assert res["d_ok"] is True
    assert res["hilbert_nd"] <= res["hyperbolic_nd"]
    assert res["trunc_refused"] is True
    assert res["trunc_refused_range"] is True


@pytest.mark.slow
def test_dryrun_cell_small_mesh():
    """Lower+compile one LM train cell on a 2x2 debug mesh (same code
    path as the 512-chip dry-run, CI-sized)."""
    out = _run_sub("""
import numpy as np, jax, json
import repro.launch.dryrun as dr
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh(2, 2)
res = dr.run_cell("llama3.2-1b", "train_4k", mesh)
print(json.dumps({"dom": res["roofline"]["dominant"],
                  "flops": res["flops_per_device"] > 0}))
""", devices=4)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["flops"] is True
