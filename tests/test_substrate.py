"""Substrate: optimizer, checkpoint/restart (fault tolerance), data
pipeline, samplers."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import (latest_step, restore_checkpoint,
                                 save_checkpoint)
from repro.data import sampler, synthetic
from repro.data.pipeline import Prefetcher
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, cosine_schedule)
from repro.optim import compression
from repro.train.fault_tolerance import StragglerDetector
from repro.train.loop import TrainLoopConfig, train_loop


def _quadratic_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}

    def loss_fn(p, batch):
        return jnp.sum((p["w"] - target) ** 2) + 0.0 * batch["x"].sum()
    return params, loss_fn


def test_adamw_converges():
    params, loss_fn = _quadratic_problem()
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=500)
    opt = adamw_init(params)
    batch = {"x": jnp.zeros(1)}
    for _ in range(300):
        g = jax.grad(lambda p: loss_fn(p, batch))(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, -2.0, 3.0],
                               atol=0.05)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               atol=1e-6)


def test_cosine_schedule_monotone_after_warmup():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    vals = [float(cosine_schedule(cfg, jnp.asarray(s)))
            for s in range(0, 100, 5)]
    assert vals[0] < vals[2]                   # warmup rises
    assert vals[-1] < vals[3]                  # decays to ~0


def test_gradient_compression_error_feedback():
    g = {"w": jnp.asarray([1.0 + 1e-4, -2.0])}
    r = compression.ef_init(g)
    total = jnp.zeros(2)
    for _ in range(64):
        c, r = compression.compress(g, r)
        total = total + compression.decompress(c)["w"]
    mean = np.asarray(total) / 64
    np.testing.assert_allclose(mean, np.asarray(g["w"]), rtol=1e-4)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.asarray(7, np.int32)}}
    save_checkpoint(str(tmp_path), 5, tree, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 5
    restored, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 5 and extra == {"note": "x"}
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_train_loop_restart_bitwise(tmp_path):
    """Kill training at step k; resume must land on the same final state
    as an uninterrupted run (the fault-tolerance contract)."""
    target = jnp.asarray([0.5, -1.5])

    def loss_fn(p, batch):
        return jnp.sum((p["w"] - target) ** 2) * batch["scale"]

    def make_batch(step):
        return {"scale": jnp.asarray(1.0 + 0.01 * (step % 3))}

    def fresh_params():
        return {"w": jnp.zeros(2)}

    cfg_full = TrainLoopConfig(
        total_steps=30, ckpt_every=10, ckpt_dir=str(tmp_path / "a"),
        optimizer=AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                              total_steps=30))
    p_full, _, _ = train_loop(loss_fn, fresh_params(), make_batch,
                              cfg_full, resume=False)

    # interrupted run: stop at 14 (ckpt at 10), then resume
    cfg_a = TrainLoopConfig(
        total_steps=15, ckpt_every=10, ckpt_dir=str(tmp_path / "b"),
        optimizer=cfg_full.optimizer)
    train_loop(loss_fn, fresh_params(), make_batch, cfg_a, resume=False)
    cfg_b = TrainLoopConfig(
        total_steps=30, ckpt_every=10, ckpt_dir=str(tmp_path / "b"),
        optimizer=cfg_full.optimizer)
    p_resumed, _, _ = train_loop(loss_fn, fresh_params(), make_batch,
                                 cfg_b, resume=True)
    np.testing.assert_allclose(np.asarray(p_full["w"]),
                               np.asarray(p_resumed["w"]), atol=1e-7)


def test_straggler_detector():
    det = StragglerDetector(window=50, k=6.0)
    for _ in range(30):
        det.record(0.1 + 0.001 * np.random.default_rng(0).random())
    assert det.record(1.5) is True
    assert det.flagged == 1


def test_prefetcher_yields_in_order():
    fetched = []

    def make_batch(step):
        return {"step": step}

    pf = Prefetcher(make_batch, start_step=3, depth=2)
    it = iter(pf)
    for _ in range(4):
        s, b = next(it)
        fetched.append(s)
    pf.close()
    assert fetched == [3, 4, 5, 6]


def test_synthetic_determinism():
    a = synthetic.token_batch(7, 3, 4, 16, 100)
    b = synthetic.token_batch(7, 3, 4, 16, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic.token_batch(7, 4, 4, 16, 100)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_neighbor_sampler_block():
    g = synthetic.random_graph(0, 500, 4000, 8, n_classes=5)
    csr = sampler.CSRGraph.from_edges(g["src"], g["dst"], 500)
    rng = np.random.default_rng(0)
    seeds = rng.choice(500, 32, replace=False)
    block = sampler.sample_block(csr, g["x"], g["labels"], seeds,
                                 (5, 3), rng=rng)
    assert block["x"].shape[0] == block["labels"].shape[0]
    assert block["src"].shape == block["dst"].shape
    ne = int(block["edge_mask"].sum())
    assert ne > 0
    # all masked edges reference in-range local nodes
    assert block["src"][:ne].max() < block["x"].shape[0]
    assert block["label_mask"].sum() == len(seeds)
    # dst of sampled edges should be reachable: seed rows get messages
    assert set(block["dst"][:ne]) & set(range(len(seeds)))
