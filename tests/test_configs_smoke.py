"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke(arch):
    mod = get(arch)
    out = mod.smoke()
    assert out
    for leaf in jax.tree_util.tree_leaves(out):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_shapes_declared(arch):
    mod = get(arch)
    shapes = mod.shapes()
    assert len(shapes) >= 3
    for name, spec in shapes.items():
        assert isinstance(spec, dict) and spec, (arch, name)


def test_40_assigned_cells_present():
    """10 assigned archs x 4 shapes (+ paper arch's own cells)."""
    n = 0
    for arch in ARCH_IDS:
        if arch == "metric-search":
            continue
        n += len(get(arch).shapes())
    assert n == 40


def test_lm_smoke_loss_reasonable():
    mod = get("llama3.2-1b")
    out = mod.smoke()
    # untrained CE should be near ln(vocab)
    import math
    v = mod.reduced_config().vocab
    assert abs(float(out["loss"]) - math.log(v)) < 2.0
