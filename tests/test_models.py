"""Model zoo unit tests: numerics of flash attention vs naive attention,
MoE routing invariants, GNN aggregation, recsys substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn, layers as L, moe as moe_lib, recsys as R
from repro.models import transformer as T


def test_flash_attention_matches_naive():
    key = jax.random.PRNGKey(0)
    b, s, h, hkv, dh = 2, 128, 8, 2, 16
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, dh))
    out = L.flash_attention(q, k, v, causal=True, block=32)

    kf = L._repeat_kv(k, h // hkv)
    vf = L._repeat_kv(v, h // hkv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / (dh ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    exp = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-5, rtol=1e-5)


def test_flash_attention_grad_finite():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 64, 4, 8))
    k = jax.random.normal(key, (1, 64, 2, 8))
    v = jax.random.normal(key, (1, 64, 2, 8))
    g = jax.grad(lambda q: jnp.sum(
        L.flash_attention(q, k, v, causal=True, block=16)))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_decode_matches_prefill_next_token():
    """decode_step at position s must equal a fresh prefill of s+1
    tokens — KV-cache correctness end-to-end."""
    cfg = T.TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=97, attn_block=16, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    p = T.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 32), 0, 97)
    next_tok = jax.random.randint(jax.random.PRNGKey(1), (2,), 0, 97)

    logits_a, cache = T.prefill(p, cfg, toks)
    # pad cache to a larger max_seq then decode
    pad = 16
    cache = {
        "k": jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0),
                                  (0, 0))),
        "v": jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0),
                                  (0, 0))),
        "len": cache["len"],
    }
    logits_dec, _ = T.decode_step(p, cfg, cache, next_tok)

    full = jnp.concatenate([toks, next_tok[:, None]], axis=1)
    logits_b, _ = T.prefill(p, cfg, full)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_b), atol=2e-3, rtol=2e-3)


def test_moe_outputs_and_aux():
    cfg = moe_lib.MoEConfig(n_experts=8, top_k=2, d_model=16, d_ff=32,
                            group_size=32, capacity_factor=2.0)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), 1, cfg)
    lp = jax.tree_util.tree_map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y, aux = moe_lib.moe_apply(lp, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0.5          # ~1 for balanced routing


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1, most tokens must be dropped => output
    rows mostly zero (residual carries them)."""
    cfg = moe_lib.MoEConfig(n_experts=4, top_k=1, d_model=8, d_ff=16,
                            group_size=64, capacity_factor=0.1)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), 1, cfg)
    lp = jax.tree_util.tree_map(lambda a: a[0], p)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
    y, _ = moe_lib.moe_apply(lp, x, cfg)
    zero_rows = int(jnp.sum(jnp.all(jnp.abs(y) < 1e-9, axis=-1)))
    assert zero_rows >= 32


def test_pna_aggregators():
    """mean/max/min/std against numpy on a known tiny graph."""
    msg = jnp.asarray([[1.0], [3.0], [5.0], [2.0]])
    dst = jnp.asarray([0, 0, 1, 1])
    agg, deg = gnn._aggregate(msg, dst, 3)
    np.testing.assert_allclose(np.asarray(deg), [2, 2, 0])
    a = np.asarray(agg)
    np.testing.assert_allclose(a[0], [2.0, 3.0, 1.0, 1.0], atol=1e-3)
    np.testing.assert_allclose(a[1], [3.5, 5.0, 2.0, 1.5], atol=1e-3)
    np.testing.assert_allclose(a[2], [0, 0, 0, 0], atol=1e-3)


def test_pna_edge_mask_equals_subgraph():
    cfg = gnn.PNAConfig(name="t", n_layers=2, d_hidden=8, d_in=4,
                        n_classes=3)
    p = gnn.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (20, 4))
    src = jnp.asarray(np.random.default_rng(0).integers(0, 20, 50))
    dst = jnp.asarray(np.random.default_rng(1).integers(0, 20, 50))
    keep = 30
    out_sub = gnn.forward(p, cfg, x, src[:keep], dst[:keep])
    mask = jnp.arange(50) < keep
    out_mask = gnn.forward(p, cfg, x, src, dst, edge_mask=mask)
    np.testing.assert_allclose(np.asarray(out_sub), np.asarray(out_mask),
                               atol=1e-5)


def test_embedding_bag_matches_loop():
    table = jnp.asarray(np.random.default_rng(0).random((50, 8)),
                        jnp.float32)
    ids = jnp.asarray([3, 7, 7, 10, 2])
    bags = jnp.asarray([0, 0, 1, 1, 1])
    out = R.embedding_bag(table, ids, bags, 2)
    exp0 = table[3] + table[7]
    exp1 = table[7] + table[10] + table[2]
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(exp0),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(exp1),
                               rtol=1e-6)


def test_dlrm_interaction_is_upper_triangle():
    cfg = R.DLRMConfig(name="t", embed=R.EmbeddingSpec((8, 8), 4),
                       bot_mlp=(13, 8, 4), top_mlp=(8, 4, 1))
    p = R.dlrm_init(jax.random.PRNGKey(0), cfg)
    n_f = cfg.n_sparse + 1
    assert p["top"][0]["w"].shape[0] == cfg.embed.dim \
        + n_f * (n_f - 1) // 2


def test_two_tower_embeddings_normalised():
    cfg = R.TwoTowerConfig(name="t",
                           embed=R.EmbeddingSpec((32, 16, 8), 8),
                           n_user_feats=2, n_item_feats=1,
                           tower_mlp=(16, 8))
    p = R.twotower_init(jax.random.PRNGKey(0), cfg)
    u = R.user_embed(p, cfg, jnp.zeros((4, 2), jnp.int32))
    norms = np.linalg.norm(np.asarray(u), axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)
