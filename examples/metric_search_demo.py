"""Full tour of the core library: four-point verification, all three
index structures, all four Hilbert-embeddable metrics, distributed
forest search.

  PYTHONPATH=src python examples/metric_search_demo.py
"""

import jax
import numpy as np

from repro.core import bruteforce, embeddings, metrics
from repro.core.tree import (build_disat, build_ght, build_mht,
                             knn_search_binary_tree, knn_search_sat,
                             search_binary_tree, search_sat)

rng = np.random.default_rng(0)

print("=== 1. four-point screening (Lemma 5) ===")
for name in ("euclidean", "jsd", "chebyshev"):
    m = metrics.get(name)
    raw = rng.random((256, 8)).astype(np.float32)
    x = np.asarray(metrics.normalise_for(m, raw))
    frac, worst = embeddings.screen_metric(
        m, x, 400, jax.random.PRNGKey(0))
    print(f"{name:10s} flag={m.four_point_property}  "
          f"empirical pass={float(frac):.3f}  worst defect={float(worst):.2e}")

print("\n=== 2. three indexes x two mechanisms (euclidean, d=10) ===")
pts = rng.random((12000, 10)).astype(np.float32)
data, queries = pts[:11900], pts[11900:11950]
t = 0.25
_, truth = bruteforce.range_search(data, queries, t,
                                   metric_name="euclidean")
for label, tree, search in [
        ("GHT", build_ght(data, "euclidean", seed=1), search_binary_tree),
        ("MHT", build_mht(data, "euclidean", seed=1), search_binary_tree),
        ("DiSAT", build_disat(data, "euclidean", seed=1), search_sat)]:
    row = [f"{label:6s}"]
    for mech in ("hyperbolic", "hilbert"):
        st = search(tree, queries, t, metric_name="euclidean",
                    mechanism=mech)
        assert st.result_sets() == truth
        row.append(f"{mech}={float(np.asarray(st.n_dist).mean()):7.0f}")
    print("  ".join(row) + "   (identical results)")

print("\n=== 3. simplex metrics (jsd / triangular) ===")
simplex = rng.random((8000, 12)).astype(np.float32)
simplex /= simplex.sum(-1, keepdims=True)
sdata, squeries = simplex[:7950], simplex[7950:7980]
for name, t in (("jsd", 0.08), ("triangular", 0.1)):
    _, truth = bruteforce.range_search(sdata, squeries, t, metric_name=name)
    tree = build_mht(sdata, name, seed=2)
    for mech in ("hyperbolic", "hilbert"):
        st = search_binary_tree(tree, squeries, t, metric_name=name,
                                mechanism=mech)
        assert st.result_sets() == truth
        print(f"{name:10s} {mech:10s} "
              f"n_dist={float(np.asarray(st.n_dist).mean()):7.0f}")

print("\n=== 4. exact k-NN (shrinking-radius Hilbert exclusion) ===")
k = 10
bf_d, bf_i = bruteforce.knn(data, queries, metric_name="euclidean", k=k)
for label, tree, knn in [
        ("MHT", build_mht(data, "euclidean", seed=1),
         knn_search_binary_tree),
        ("DiSAT", build_disat(data, "euclidean", seed=1), knn_search_sat)]:
    row = [f"{label:6s}"]
    for mech in ("hyperbolic", "hilbert"):
        st = knn(tree, queries, k, metric_name="euclidean", mechanism=mech)
        assert np.array_equal(np.asarray(st.ids), np.asarray(bf_i))
        row.append(f"{mech}={float(np.asarray(st.n_dist).mean()):7.0f}")
    print("  ".join(row) + f"   (k={k}, ids == brute force)")

print("\nall exact; Hilbert always cheaper.")
