"""Two-tower retrieval serving with the paper's technique.

Trains a small two-tower model (in-batch sampled softmax), embeds a
candidate corpus, then serves `retrieval_cand`-style queries two ways:

  1. brute-force MXU dot-scan + top-k          (dry-run lowering)
  2. Hilbert-exclusion metric index over d_cos (paper §5.5 space)

and checks both return the same neighbours.

  PYTHONPATH=src python examples/serve_retrieval.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.core import bruteforce
from repro.core.tree import build_mht, search_binary_tree
from repro.data import synthetic
from repro.models import recsys as R
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

mod = get("two-tower-retrieval")
cfg = mod.reduced_config()
params = R.twotower_init(jax.random.PRNGKey(0), cfg)

# --- short training run ----------------------------------------------------
opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=200)
opt = adamw_init(params)


@jax.jit
def step(params, opt, uids, iids):
    loss, g = jax.value_and_grad(
        lambda p: R.twotower_loss(p, cfg, uids, iids))(params)
    params, opt, _ = adamw_update(params, g, opt, opt_cfg)
    return params, opt, loss


for s in range(200):
    b = synthetic.retrieval_batch(
        0, s, 64, cfg.n_user_feats, cfg.n_item_feats,
        cfg.embed.vocab_sizes[0], cfg.embed.vocab_sizes[cfg.n_user_feats])
    params, opt, loss = step(params, opt, jnp.asarray(b["user_ids"]),
                             jnp.asarray(b["item_ids"]))
    if s % 50 == 0:
        print(f"train step {s:4d} loss {float(loss):.4f}")

# --- embed a candidate corpus ------------------------------------------------
n_cand = 20000
rng = np.random.default_rng(1)
cand_ids = np.stack([rng.integers(0, v, n_cand) for v in
                     cfg.embed.vocab_sizes[cfg.n_user_feats:]],
                    axis=1).astype(np.int32)
cand_vecs = np.asarray(R.item_embed(params, cfg, jnp.asarray(cand_ids)))

# --- serve: one query, 20k candidates ---------------------------------------
uq = jnp.asarray(rng.integers(0, 16, (1, cfg.n_user_feats)), jnp.int32)
scores, top_bf = R.retrieval_scores(params, cfg, uq, jnp.asarray(cand_vecs),
                                    k=10)
top_bf = set(np.asarray(top_bf)[0].tolist())
print("\nbrute-force top-10:", sorted(top_bf))

# metric-index backend: d_cos = sqrt(1 - dot) is rank-equivalent to the
# dot score on normalised towers and HAS the four-point property
u = np.asarray(R.user_embed(params, cfg, uq))
d_cos = np.sqrt(np.maximum(1.0 - cand_vecs @ u[0], 0.0))
kth = np.sort(d_cos)[9]                      # radius covering top-10

tree = build_mht(cand_vecs, "cosine", leaf_size=32, seed=0)
st = search_binary_tree(tree, u, kth + 1e-6, metric_name="cosine",
                        mechanism="hilbert", r_cap=64)
top_ix = set(st.result_sets()[0])
nd = float(np.asarray(st.n_dist)[0])
print(f"hilbert-index range search: {nd:.0f} distance evals "
      f"({100 * nd / n_cand:.1f}% of corpus)")
assert top_bf <= top_ix, (top_bf, top_ix)
print("index result covers the brute-force top-10: True")
