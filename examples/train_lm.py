"""End-to-end driver: train a (reduced) llama-family model for a few
hundred steps with the full substrate — data pipeline, AdamW, cosine
schedule, checkpointing, preemption-safe loop — and verify the loss
drops.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

(The ~100M-param full-size equivalent is the same call without
--reduced on a TPU pod; this container runs the reduced config.)
"""

import argparse
import sys

from repro.launch import train as train_launcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    sys.argv = ["train", "--arch", "llama3.2-1b", "--reduced",
                "--steps", str(args.steps), "--batch", "8",
                "--seq", "128", "--ckpt-dir", "/tmp/repro_lm_ckpt",
                "--lr", "1e-3"]
    train_launcher.main()


if __name__ == "__main__":
    main()
