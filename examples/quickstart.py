"""Quickstart: the paper's contribution in 40 lines.

Builds a metric index over Euclidean vectors, runs the same range query
under Hyperbolic and Hilbert exclusion, and shows (a) identical results,
(b) fewer distance evaluations with Hilbert — the paper's entire claim.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import bruteforce
from repro.core.tree import build_mht, search_binary_tree

rng = np.random.default_rng(0)
data = rng.random((20000, 10)).astype(np.float32)     # unit hypercube
queries = rng.random((32, 10)).astype(np.float32)
t = 0.25                                              # range threshold

# ground truth
counts, truth = bruteforce.range_search(data, queries, t,
                                        metric_name="euclidean")

# one index, two exclusion mechanisms
tree = build_mht(data, "euclidean", leaf_size=32, seed=0)
for mechanism in ("hyperbolic", "hilbert"):
    stats = search_binary_tree(tree, queries, t, metric_name="euclidean",
                               mechanism=mechanism)
    assert stats.result_sets() == truth, "exact search violated!"
    nd = float(np.asarray(stats.n_dist).mean())
    print(f"{mechanism:11s}: {nd:8.0f} distance evals/query "
          f"({100 * nd / len(data):5.2f}% of brute force)  "
          f"results identical: True")

print("\nHilbert Exclusion: same answers, fewer distance evaluations.")
